"""Memory-planner tests.

Device-free units exercise the footprint algebra, the paper's §3.1
minimal-partition-group rule (``min_partition_size`` / ``resolve_scale``)
and the autotuner's ``hbm_budget_gb`` gate over duck-typed stubs; the
predicted-vs-compiled property runs through the 8-virtual-device subprocess
harness (tests/memplan_harness.py), which is also the CI smoke gate.

Degenerate cases covered per the ISSUE: a single-device mesh, a partition
group spanning the whole world, ``prefetch_carry='remat'`` bitwise-equal
losses vs ``'stored'`` (harness), and a budget smaller than any candidate
(a clear :class:`MemoryBudgetError`, never a silent empty plan).
"""

import dataclasses
import pathlib

import pytest

from harness_util import run_harness
from repro.core import memplan as M
from repro.core.autotune import rank_policies, resolve_config, resolve_scale
from repro.core.comm import GatherPolicy, SyncPolicy
from repro.core.linkmodel import GIB
from repro.core.memplan import (
    DeviceGrid, MemoryBudgetError, min_partition_size,
    partition_size_candidates, predict_footprint,
)
from repro.core.mics import MiCSConfig

HARNESS = pathlib.Path(__file__).parent / "memplan_harness.py"


# ---------------------------------------------------------------------------
# device-free stubs (same duck-typing contract as test_autotune.py)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StubTopo:
    axes: dict
    partition_axes: tuple
    replication_axes: tuple

    def axis_size(self, name):
        return self.axes[name]

    @property
    def partition_size(self):
        out = 1
        for a in self.partition_axes:
            out *= self.axes[a]
        return out

    @property
    def replication_degree(self):
        out = 1
        for a in self.replication_axes:
            out *= self.axes[a]
        return out


@dataclasses.dataclass(frozen=True)
class StubPool:
    name: str


class StubModel:
    """Three pools shaped like a small LM: embed + scanned stack + head."""

    def __init__(self, stack=8, flat_len=65536):
        self.pools = (StubPool("layers"),)
        self._shapes = {
            "embed": (1, 1, 16384),
            "layers": (stack, 1, flat_len),
            "head": (1, 1, 20480),
        }

    def all_pools(self):
        return (StubPool("embed"), StubPool("layers"), StubPool("head"))

    def global_flat_shapes(self):
        return dict(self._shapes)


def topo_single(p=16, repl=2):
    return StubTopo({"shard": p, "repl": repl}, ("shard",), ("repl",))


# ---------------------------------------------------------------------------
# footprint algebra
# ---------------------------------------------------------------------------

def test_footprint_components_and_ordering():
    model = StubModel()
    gp_stored = GatherPolicy(prefetch=True)
    gp_remat = GatherPolicy(prefetch=True, prefetch_carry="remat")
    gp_serial = GatherPolicy(prefetch=False)
    sp = SyncPolicy()
    grid = DeviceGrid(partition_size=4, replication_degree=2)
    plans = {
        name: predict_footprint(model, grid, g, sp, micro_steps=2)
        for name, g in (("stored", gp_stored), ("remat", gp_remat),
                        ("serial", gp_serial))
    }
    # the carry ordering the planner exists to price
    assert plans["stored"].total_bytes > plans["remat"].total_bytes \
        > plans["serial"].total_bytes
    # states are identical (they do not depend on the schedule)
    assert len({p.args_bytes for p in plans.values()}) == 1
    comp = plans["stored"].components
    for key in ("gather_buffers", "grad_accum", "boundary_reduced",
                "prefetch_carry", "hop2_staging"):
        assert comp[key] > 0, (key, comp)
    assert "prefetch_carry" not in plans["serial"].components
    # remat's carry is the O(layers x shard) term: well below stored's
    # O(layers x flat_len) (the gap widens with p — at p=4 it is ~4x)
    assert plans["remat"].components["prefetch_carry"] \
        < plans["stored"].components["prefetch_carry"] / 2


def test_footprint_scales_with_partition_size():
    """Doubling p halves the sharded states but not the gathered buffers —
    the exact trade the paper's minimal-group rule walks."""
    model, sp = StubModel(), SyncPolicy()
    gp = GatherPolicy(prefetch=True)
    p2 = predict_footprint(model, DeviceGrid(2, 8), gp, sp)
    p8 = predict_footprint(model, DeviceGrid(8, 2), gp, sp)
    assert p8.args_bytes < p2.args_bytes
    assert p8.components["gather_buffers"] == p2.components["gather_buffers"]


def test_footprint_degenerate_grids():
    model, sp = StubModel(), SyncPolicy()
    gp = GatherPolicy(wire_dtype="int8", prefetch=True)
    # single device: nothing on the wire -> no quant scratch, no hop-2
    one = predict_footprint(model, DeviceGrid(1, 1), gp,
                            SyncPolicy(hop1_wire_dtype="int8"))
    assert "int8_wire_scratch" not in one.components
    assert "qgz_scratch" not in one.components
    assert "hop2_staging" not in one.components
    # partition group == world: no replication -> no hop-2 staging
    world = predict_footprint(model, DeviceGrid(16, 1), gp, sp)
    assert "hop2_staging" not in world.components
    assert "int8_wire_scratch" in world.components


def test_footprint_encdec_decoder_pools_price_stored_carry():
    """models/lm.py falls back to the stored carry for enc-dec *decoder*
    pools even under remat (a custom VJP may not close over the
    gradient-carrying encoder output); the planner must price them as
    stored so the budget gate never under-predicts."""
    class EncDecModel:
        class cfg:  # noqa: D106 - duck-typed ArchConfig surface
            family = "encdec"
            d_model = 64
            vocab = 256

        def __init__(self):
            self.pools = (StubPool("enc_layers"), StubPool("dec_layers"))
            self._shapes = {
                "embed": (1, 1, 16384),
                "enc_layers": (4, 1, 65536),
                "dec_layers": (4, 1, 65536),
                "head": (1, 1, 20480),
            }

        def all_pools(self):
            return (StubPool("embed"), StubPool("enc_layers"),
                    StubPool("dec_layers"), StubPool("head"))

        def global_flat_shapes(self):
            return dict(self._shapes)

    grid, sp = DeviceGrid(4, 2), SyncPolicy()
    stored = predict_footprint(EncDecModel(), grid,
                               GatherPolicy(prefetch=True), sp)
    remat = predict_footprint(
        EncDecModel(), grid,
        GatherPolicy(prefetch=True, prefetch_carry="remat"), sp)
    s_carry = stored.components["prefetch_carry"]
    r_carry = remat.components["prefetch_carry"]
    # remat only relieves the encoder pool; the decoder half stays stored
    assert s_carry / 2 < r_carry < s_carry


def test_footprint_activation_terms_need_shapes():
    class CfgModel(StubModel):
        class cfg:  # noqa: D106 - duck-typed ArchConfig surface
            d_model = 64
            vocab = 256
        tp = 1
        vocab_padded = 256

    sp = SyncPolicy()
    gp = GatherPolicy(prefetch=True)
    bare = predict_footprint(CfgModel(), DeviceGrid(4, 2), gp, sp)
    sized = predict_footprint(CfgModel(), DeviceGrid(4, 2), gp, sp,
                              local_batch=2, seq=128)
    assert "activation_ckpt" not in bare.components
    assert sized.components["activation_ckpt"] > 0
    assert sized.components["logits_ce"] > 0
    assert sized.args_bytes > bare.args_bytes  # the batch itself


# ---------------------------------------------------------------------------
# the §3.1 rule: minimal partition group that fits
# ---------------------------------------------------------------------------

def test_partition_size_candidates():
    assert partition_size_candidates(16) == [1, 2, 4, 8, 16]
    assert partition_size_candidates(12) == [1, 2, 3, 4, 6, 12]
    with pytest.raises(ValueError):
        partition_size_candidates(0)


def test_min_partition_size_picks_minimal():
    model = StubModel()
    # p=1 needs ~3x full states; find a budget that p=4 just satisfies
    need = {p: predict_footprint(
        model, DeviceGrid(p, 16 // p), GatherPolicy(prefetch=True),
        SyncPolicy()).total_bytes for p in (1, 2, 4, 8, 16)}
    budget_gb = (need[4] + 1) / GIB
    assert need[2] > need[4] + 1  # the budget really excludes p=2
    p, carry, plan = min_partition_size(
        model, data_extent=16, hbm_budget_gb=budget_gb)
    assert p == 4 and carry == "stored"
    assert plan.total_bytes <= budget_gb * GIB


def test_min_partition_size_remat_rescues_smaller_group():
    """A budget between a group's remat and stored footprints must pick the
    SMALLER group with remat, not grow the group — smaller groups keep
    collectives on faster tiers, the whole point of scale-aware
    partitioning."""
    model = StubModel()
    gp = GatherPolicy(prefetch=True)
    sp = SyncPolicy()
    stored4 = predict_footprint(model, DeviceGrid(4, 4), gp, sp).total_bytes
    remat4 = predict_footprint(
        model, DeviceGrid(4, 4),
        dataclasses.replace(gp, prefetch_carry="remat"), sp).total_bytes
    assert remat4 < stored4
    budget_gb = (remat4 + stored4) / 2 / GIB
    p, carry, _plan = min_partition_size(
        model, data_extent=16, hbm_budget_gb=budget_gb,
        carries=("stored", "remat"))
    p_stored_only, carry_stored, _ = min_partition_size(
        model, data_extent=16, hbm_budget_gb=budget_gb)
    assert (p, carry) == (4, "remat")
    assert carry_stored == "stored" and p_stored_only > p


def test_min_partition_size_budget_too_small_is_clear_error():
    with pytest.raises(MemoryBudgetError) as ei:
        min_partition_size(StubModel(), data_extent=16,
                           hbm_budget_gb=1e-6)
    msg = str(ei.value)
    assert "no partition group fits" in msg
    assert "GiB per device" in msg


# ---------------------------------------------------------------------------
# autotuner integration: the hbm_budget_gb gate
# ---------------------------------------------------------------------------

def test_rank_policies_prices_memory():
    plan = rank_policies(StubModel(), topo_single(p=4, repl=2), "v5e",
                         micro_steps=2)
    assert all(c.mem_bytes > 0 for c in plan.candidates)
    assert "mem_GB" in plan.table()
    assert plan.hbm_budget_gb is None
    # without a budget the grid has no remat rows (pure cost, never wins)
    assert {c.gather.prefetch_carry for c in plan.candidates} == {"stored"}


def test_rank_policies_budget_filters_and_falls_back_to_remat():
    model, topo = StubModel(), topo_single(p=4, repl=2)
    free = rank_policies(model, topo, "v5e", micro_steps=2)
    stored_best = free.chosen
    # a budget below the stored footprint but above remat's forces the
    # mitigation knob: remat is slower (one extra gather per layer) but fits
    remat_plan = rank_policies(model, topo, "v5e", micro_steps=2,
                               hbm_budget_gb=1e6)  # effectively unlimited
    remat_rows = [c for c in remat_plan.candidates
                  if c.gather.prefetch_carry == "remat"]
    assert remat_rows, "budgeted ranking must include the remat axis"
    budget_gb = (min(c.mem_bytes for c in remat_rows) + 1) / GIB
    gated = rank_policies(model, topo, "v5e", micro_steps=2,
                          hbm_budget_gb=budget_gb)
    assert gated.chosen.gather.prefetch_carry == "remat"
    assert gated.chosen.mem_bytes <= budget_gb * GIB
    assert stored_best.mem_bytes > budget_gb * GIB
    assert gated.chosen.t_comm_s >= stored_best.t_comm_s


def test_rank_policies_budget_too_small_raises():
    with pytest.raises(MemoryBudgetError):
        rank_policies(StubModel(), topo_single(p=4, repl=2), "v5e",
                      micro_steps=2, hbm_budget_gb=1e-6)


def test_resolve_config_applies_budget(topo1):
    model, topo = StubModel(), topo_single(p=4, repl=2)
    remat_plan = rank_policies(model, topo, "v5e", micro_steps=2,
                               hbm_budget_gb=1e6)
    remat_rows = [c for c in remat_plan.candidates
                  if c.gather.prefetch_carry == "remat"]
    budget_gb = (min(c.mem_bytes for c in remat_rows) + 1) / GIB
    mcfg = MiCSConfig(micro_steps=2, policy="auto", link_profile="v5e",
                      hbm_budget_gb=budget_gb)
    resolved, plan = resolve_config(mcfg, model, topo)
    assert plan.hbm_budget_gb == budget_gb
    assert resolved.prefetch_carry == "remat"
    # and the resolved config reconstructs the chosen policy end to end
    from repro.core.comm import CommEngine

    eng = CommEngine.from_config(topo1, resolved)
    assert eng.gather_policy.prefetch_carry == "remat"


def test_resolve_scale_minimal_group():
    model = StubModel()
    need4 = predict_footprint(
        model, DeviceGrid(4, 4), GatherPolicy(prefetch=True),
        SyncPolicy()).total_bytes
    mcfg = MiCSConfig(micro_steps=1, hbm_budget_gb=(need4 + 1) / GIB)
    p, carry, plan = resolve_scale(model, mcfg, data_extent=16)
    assert p == 4 and carry == "stored"
    with pytest.raises(ValueError):
        resolve_scale(model, MiCSConfig(), data_extent=16)
    with pytest.raises(MemoryBudgetError):
        resolve_scale(model, dataclasses.replace(mcfg, hbm_budget_gb=1e-6),
                      data_extent=16)


def test_config_validation():
    with pytest.raises(ValueError):
        MiCSConfig(prefetch_carry="offload")
    with pytest.raises(ValueError):
        MiCSConfig(hbm_budget_gb=0.0)
    with pytest.raises(ValueError):
        GatherPolicy(prefetch_carry="none")


# ---------------------------------------------------------------------------
# multi-device harness: predicted footprint == compiled memory analysis
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def harness_results():
    return run_harness(HARNESS)


CHECKS = [
    "footprint_match", "footprint_degenerate", "remat_lowers_peak",
    "census_match_remat", "carried_buffer_census", "offload_lowers_peak",
]


@pytest.mark.parametrize("name", CHECKS)
def test_memplan_check(harness_results, name):
    res = harness_results.get(name)
    assert res is not None, f"harness did not run {name}"
    assert res["ok"], f"{name}: {res.get('err')}\n{res.get('tb', '')}"


def test_footprint_matrix_covered(harness_results):
    detail = harness_results.get("footprint_match_detail")
    assert detail is not None
    combos = {f"{t}/{c}" for t in ("flat", "inner_first", "outer_first")
              for c in ("stored", "remat")}
    assert combos <= set(detail)
    for combo, row in detail.items():
        assert row["predicted_args_bytes"] == row["measured_args_bytes"]
        assert abs(row["temp_ratio"] - 1.0) <= M.MEM_RTOL, (combo, row)


def test_remat_saving_is_the_carry(harness_results):
    """The compiled stored-vs-remat temp delta is dominated by the carry
    component the planner prices."""
    saving = harness_results["remat_lowers_peak_detail"]["saving_bytes"]
    det = harness_results["footprint_match_detail"]
    pred_delta = (det["inner_first/stored"]["components"]["prefetch_carry"]
                  - det["inner_first/remat"]["components"]["prefetch_carry"])
    assert saving > 0
    assert abs(pred_delta - saving) <= 0.5 * saving


def test_offload_peak_accounting(harness_results):
    """carry_offload='host' + offload_opt shrink the compiled peak the way
    the planner predicts: temps lose the carry residual, args lose the
    fp32 m/v shards (2/3 of the 3x-fp32 state), args stay exact."""
    det = harness_results["offload_lowers_peak_detail"]
    s, hc, ho = det["stored"], det["host_carry"], det["host_carry_opt"]
    for row in (s, hc, ho):
        assert row["predicted_args_bytes"] == row["measured_args_bytes"]
    assert hc["measured_temp_bytes"] < s["measured_temp_bytes"]
    # m/v leave the donated args: the drop is ~2/3 of the state bytes
    drop = s["measured_args_bytes"] - ho["measured_args_bytes"]
    assert drop > 0.5 * s["measured_args_bytes"], det
