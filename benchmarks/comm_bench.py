"""Serial vs double-buffered-prefetch gather schedules on the host mesh,
plus the autotuner's predicted-vs-measured ledger per gather policy.

Run standalone (benchmarks/run.py invokes it as a subprocess so the main
benchmark process keeps its single CPU device):

  PYTHONPATH=src python benchmarks/comm_bench.py

Prints one JSON object (saved as BENCH_comm.json by run.py):

* per-schedule wall time per training step, the HLO-census
  gathered-bytes/collective counts, the carried-gather prefetch evidence,
  and the loss trajectories (which must be bitwise equal — the schedules
  differ only in *when* gathers are issued, never in values);
* a ``policies`` section: for each gather policy (flat / inner_first /
  outer_first bf16 wire, inner_first int8), the analytical per-stage wire
  bytes (core/autotune.predict_traffic) against the measured census of the
  compiled step, and the α-β modeled comm time under two link profiles
  (v5e + efa-100g, core/linkmodel.py);
* the autotuner's full ranked table per profile (``autotune_rankings``).
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core.autotune import (
    compare_census, cost_candidate, predict_traffic, rank_policies,
)
from repro.core.comm import GatherPolicy, SyncPolicy
from repro.core.linkmodel import get_profile
from repro.core.mics import (
    MiCSConfig, build_train_step, init_state, init_state_shapes,
    make_batch_shapes,
)
from repro.core.topology import MiCSTopology, make_host_mesh
from repro.models.build import build_model
from repro.optim.adamw import OptConfig
from repro.roofline.hlo_stats import analyze

STEPS = 8
MICRO = 2

PROFILES = ("v5e", "efa-100g")
# (label, GatherPolicy fields, MiCSConfig fields) — >= 3 policies for the
# predicted-vs-measured ledger (acceptance criterion of ISSUE 2).
POLICIES = (
    ("flat@bf16", ("flat", "bf16"), dict(hierarchical=False)),
    ("inner_first@bf16", ("inner_first", "bf16"), dict()),
    ("outer_first@bf16", ("outer_first", "bf16"),
     dict(gather_order="outer_first")),
    ("inner_first@int8", ("inner_first", "int8"), dict(quant_gather=True)),
)


def run(steps: int = STEPS) -> dict:
    cfg = smoke_variant(get_config("llama3.2-1b"))
    mesh = make_host_mesh(1, 1, 4, 2)  # p=4 partition group, tp=2
    topo = MiCSTopology(mesh)
    model = build_model(cfg, tp=2)
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))

    rng = np.random.default_rng(5)
    b, t = 8, 32
    batch = {
        "tokens": jnp.array(rng.integers(0, cfg.vocab, (MICRO, b, t)),
                            jnp.int32),
        "targets": jnp.array(rng.integers(0, cfg.vocab, (MICRO, b, t)),
                             jnp.int32),
        "mask": jnp.ones((MICRO, b, t), jnp.float32),
    }

    out = {"mesh": mesh_shape, "partition_size": topo.partition_size,
           "steps": steps, "micro_steps": MICRO}
    for label, prefetch in (("serial", False), ("prefetch", True)):
        mcfg = MiCSConfig(micro_steps=MICRO, prefetch=prefetch)
        step = build_train_step(model, topo, mcfg,
                                OptConfig(total_steps=100, warmup_steps=0,
                                          lr_max=3e-3))
        stats = analyze(
            step.lower(init_state_shapes(model),
                       make_batch_shapes(model, MICRO * b, t, MICRO))
                .compile().as_text(),
            mesh_shape,
            partition_axes=topo.partition_axes,
            replication_axes=topo.replication_axes)
        gather_stages = {k: v for k, v in stats["by_stage"].items()
                         if k.startswith("param_gather")}

        state = init_state(model, topo, seed=11)
        state, m = step(state, batch)  # compile + warm
        jax.block_until_ready(m["loss"])
        losses = []
        t0 = time.perf_counter()
        for _ in range(steps):
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
        dt = (time.perf_counter() - t0) / steps

        out[label] = {
            "us_per_step": round(dt * 1e6, 1),
            "gathered_wire_bytes": sum(
                v["wire_bytes"] for v in gather_stages.values()),
            "param_gather_count": sum(
                v["count"] for v in gather_stages.values()),
            "carried_all_gathers": stats["prefetch"]["carried_all_gathers"],
            "total_wire_bytes": stats["total_wire_bytes"],
            "losses": losses,
        }
    out["loss_bitwise_equal"] = out["serial"]["losses"] \
        == out["prefetch"]["losses"]
    out["speedup"] = round(
        out["serial"]["us_per_step"] / out["prefetch"]["us_per_step"], 3)
    out["policies"] = policy_ledger(model, topo, mesh_shape)
    out["autotune_rankings"] = {
        name: rank_policies(model, topo, name, micro_steps=MICRO,
                            prefetch=True).describe()
        for name in PROFILES
    }
    return out


def policy_ledger(model, topo, mesh_shape) -> dict:
    """Predicted-vs-measured per gather policy, on two link profiles.

    Measured: per-stage census wire bytes of the compiled (serial) train
    step.  Predicted: core/autotune.predict_traffic with
    ``upcast_float_collectives=True`` (the census is compiled for host
    CPUs, where XLA widens bf16 collectives to f32).  Modeled times use
    the un-upcast traffic — the real wire cost on each profile.
    """
    ledger = {}
    for label, (topology, wire), mcfg_kw in POLICIES:
        mcfg = MiCSConfig(micro_steps=MICRO, prefetch=False, **mcfg_kw)
        step = build_train_step(model, topo, mcfg,
                                OptConfig(total_steps=100, warmup_steps=0,
                                          lr_max=3e-3))
        stats = analyze(
            step.lower(init_state_shapes(model),
                       make_batch_shapes(model, MICRO * 8, 32, MICRO))
                .compile().as_text(),
            mesh_shape,
            partition_axes=topo.partition_axes,
            replication_axes=topo.replication_axes)
        gp = GatherPolicy(topology, wire, None, False)
        sp = SyncPolicy()
        predicted = predict_traffic(model, topo, gp, sp, micro_steps=MICRO,
                                    upcast_float_collectives=True)
        cmp = compare_census(predicted["by_stage"], stats["by_stage"])
        entry = {
            "predicted_vs_measured": cmp,
            "byte_match": all(
                abs(row["ratio"] - 1.0) <= 0.02 for row in cmp.values()),
            "measured_total_wire_bytes": stats["total_wire_bytes"],
            "modeled_t_comm_us": {},
        }
        for name in PROFILES:
            cand = cost_candidate(model, topo, get_profile(name), gp, sp,
                                  micro_steps=MICRO)
            entry["modeled_t_comm_us"][name] = round(cand.t_comm_s * 1e6, 2)
        ledger[label] = entry
    return ledger


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
