"""Serial vs double-buffered-prefetch gather schedules on the host mesh,
plus the autotuner's predicted-vs-measured ledger per gather policy and the
boundary scheduler's serial-vs-bucketed hop-2 ledger.

Run standalone (benchmarks/run.py invokes it as a subprocess so the main
benchmark process keeps its single CPU device):

  PYTHONPATH=src python benchmarks/comm_bench.py [--smoke] [--steps N]
      [--warmup N] [--check]

``--smoke`` runs the CI-sized variant (fewer timing steps, same coverage).
Prints one JSON object (saved as BENCH_comm.json by run.py):

* per-schedule wall time per training step, the HLO-census
  gathered-bytes/collective counts, the carried-gather prefetch evidence,
  and the loss trajectories (which must be bitwise equal — the schedules
  differ only in *when* gathers are issued, never in values);
* a ``policies`` section: for each gather/sync policy (flat / inner_first /
  outer_first bf16 wire, inner_first int8, and the qgZ rows shipping the
  int8 block-quantized hop-1 gradient wire), the analytical per-stage wire
  bytes (core/autotune.predict_traffic) against the measured census of the
  compiled step, the α-β modeled comm time under two link profiles (v5e +
  efa-100g, core/linkmodel.py), a measured wall time, and the
  ``fit_inputs`` stage ledger that ``tools/fit_profile.py`` fits per-tier
  (α, β) from;
* a ``boundary`` section on a replicated mesh (hop 2 live): serial vs
  bucketed-exact vs bucketed-approx (``clip_mode='approx'``: AdamW
  pipelined under the next bucket's hop-2 with a one-bucket-stale clip
  factor) vs host-offloaded (``carry_offload='host'`` +
  ``offload_opt=True``) boundary cells — exact/offload trajectories
  bitwise equal, approx within ``APPROX_CLIP_LOSS_RTOL``, per-cell
  measured wall times, the bucket-granular hop-2 census, and an
  ``overlap`` roll-up of measured step time vs the link model's predicted
  exposed-hop-2 time per cell and profile;
* a ``cells`` section in the shared perf-matrix schema
  (repro.bench.measure): every timed cell carries its declarative config
  + config hash, the timing samples with median/MAD/IQR variance, and
  its local contract verdict;
* the autotuner's full ranked table per profile (``autotune_rankings``).

This script is the ``comm`` suite of the declarative perf matrix
(``benchmarks/matrix.py``); ``--check`` is a thin shim that applies
exactly the gates ``repro.bench.matrixdef`` declares for this suite —
bitwise/census/rtol contracts per cell, and the variance-aware step-time
regression gates of the non-serial boundary cells against the same-run
serial reference (the host-offload cell gets a wider threshold for its
documented CPU io_callback overhead).
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.bench import measure as MS
from repro.bench.matrixdef import COMM_BOUNDARY_CELLS, COMM_POLICY_LABELS
from repro.configs import get_config, smoke_variant
from repro.core.autotune import (
    compare_census, cost_candidate, cost_hop2_schedule, predict_traffic,
    rank_policies,
)
from repro.core import memplan
from repro.core.comm import CommEngine
from repro.core.hostoffload import stash_clear
from repro.core.linkmodel import get_profile
from repro.core.mics import (
    MiCSConfig, build_train_step, init_state, init_state_shapes,
    make_batch_shapes,
)
from repro.core.schedule import APPROX_CLIP_LOSS_RTOL, plan_boundary
from repro.core.topology import MiCSTopology, make_host_mesh
from repro.models.build import build_model
from repro.optim.adamw import OptConfig
from repro.roofline.hlo_stats import analyze

STEPS = 8
WARMUP = 1  # timed loops discard this many post-compile steps
MICRO = 2
BOUNDARY_BUCKET_MB = 0.05  # small enough to split the smoke model's pools

PROFILES = ("v5e", "efa-100g")
# (label, MiCSConfig fields) — >= 3 policies for the predicted-vs-measured
# ledger (acceptance criterion of ISSUE 2); the GatherPolicy/SyncPolicy are
# derived via CommEngine.from_config so the ledger prices exactly what the
# step runs.  The qgZ rows ship the int8 hop-1 gradient wire (ISSUE 4);
# the +host row streams the prefetch carry over the host tier, giving
# tools/fit_profile.py a ``tier='host'`` stage to constrain (α, β) from.
# Labels are pinned by repro.bench.matrixdef.COMM_POLICY_LABELS — the
# declared matrix cells — so coverage drift fails the matrix loudly.
POLICIES = tuple(zip(COMM_POLICY_LABELS, (
    dict(hierarchical=False),
    dict(),
    dict(gather_order="outer_first"),
    dict(quant_gather=True),
    dict(hop1_wire_dtype="int8"),
    dict(quant_gather=True, hop1_wire_dtype="int8"),
    dict(prefetch=True, carry_offload="host"),
    # second host row at a different bytes-per-event ratio (fp32 carry is
    # 2x the bytes of bf16 at the same event count) — separates the host
    # α from its β in the fit
    dict(prefetch=True, gather_dtype="float32", carry_offload="host"),
)))

# Boundary cells (replicated mesh): the bitwise-exact schedules, the
# approximate-clip pipeline, and the host-offloaded cell (carry + AdamW
# moments streamed through the host stash; numerics still bitwise-exact).
# Cell labels pinned by matrixdef.COMM_BOUNDARY_CELLS, thresholds by
# matrixdef.COMM_BOUNDARY_THRESHOLDS.
BOUNDARY_CELLS = tuple(zip(COMM_BOUNDARY_CELLS, (
    dict(boundary_schedule="serial"),
    dict(boundary_schedule="bucketed"),
    dict(boundary_schedule="bucketed", clip_mode="approx"),
    dict(boundary_schedule="bucketed", carry_offload="host",
         offload_opt=True),
)))


def _timed_steps(step, state, batch, steps, warmup):
    """Run ``warmup + steps`` training steps; per-step wall times (each
    blocked on the loss, so the samples are honest) + the timed-loop loss
    trajectory."""
    m = None
    for _ in range(warmup):
        state, m = step(state, batch)
        jax.block_until_ready(m["loss"])
    samples, traj = [], []
    for _ in range(steps):
        t0 = time.perf_counter()
        state, m = step(state, batch)
        traj.append((float(m["loss"]), float(m["grad_norm"])))
        samples.append(time.perf_counter() - t0)
    return state, MS.TimingStats(tuple(samples), warmup=warmup), traj


def run(steps: int = STEPS, warmup: int = WARMUP) -> dict:
    cfg = smoke_variant(get_config("llama3.2-1b"))
    mesh = make_host_mesh(1, 1, 4, 2)  # p=4 partition group, tp=2
    topo = MiCSTopology(mesh)
    model = build_model(cfg, tp=2)
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))

    rng = np.random.default_rng(5)
    b, t = 8, 32
    batch = {
        "tokens": jnp.array(rng.integers(0, cfg.vocab, (MICRO, b, t)),
                            jnp.int32),
        "targets": jnp.array(rng.integers(0, cfg.vocab, (MICRO, b, t)),
                             jnp.int32),
        "mask": jnp.ones((MICRO, b, t), jnp.float32),
    }

    def cell_config(section, label, **extra):
        return dict(suite="comm", section=section, cell=label,
                    mesh=mesh_shape, model=cfg.name, micro_steps=MICRO,
                    batch=[b, t], steps=steps, warmup=warmup, **extra)

    cells = {}
    out = {"mesh": mesh_shape, "partition_size": topo.partition_size,
           "steps": steps, "warmup": warmup, "micro_steps": MICRO}
    for label, prefetch in (("serial", False), ("prefetch", True)):
        mcfg = MiCSConfig(micro_steps=MICRO, prefetch=prefetch)
        step = build_train_step(model, topo, mcfg,
                                OptConfig(total_steps=100, warmup_steps=0,
                                          lr_max=3e-3))
        stats = analyze(
            step.lower(init_state_shapes(model),
                       make_batch_shapes(model, MICRO * b, t, MICRO))
                .compile().as_text(),
            mesh_shape,
            partition_axes=topo.partition_axes,
            replication_axes=topo.replication_axes)
        gather_stages = {k: v for k, v in stats["by_stage"].items()
                         if k.startswith("param_gather")}

        state = init_state(model, topo, seed=11)
        _state, timing, traj = _timed_steps(step, state, batch, steps,
                                            warmup)
        out[label] = {
            "us_per_step": round(timing.median_s * 1e6, 1),
            "gathered_wire_bytes": sum(
                v["wire_bytes"] for v in gather_stages.values()),
            "param_gather_count": sum(
                v["count"] for v in gather_stages.values()),
            "carried_all_gathers": stats["prefetch"]["carried_all_gathers"],
            "total_wire_bytes": stats["total_wire_bytes"],
            "losses": [loss for loss, _gn in traj],
        }
        cells[f"comm/gather/{label}"] = MS.timing_cell(
            cell_config("gather", label, schedule=label), timing,
            metrics={
                "gathered_wire_bytes": out[label]["gathered_wire_bytes"],
                "total_wire_bytes": out[label]["total_wire_bytes"],
                "carried_all_gathers": out[label]["carried_all_gathers"],
            })
    out["loss_bitwise_equal"] = out["serial"]["losses"] \
        == out["prefetch"]["losses"]
    cells["comm/gather/prefetch"]["ok"] = out["loss_bitwise_equal"]
    if not out["loss_bitwise_equal"]:
        cells["comm/gather/prefetch"]["detail"] = "prefetch changed the loss"
    out["speedup"] = round(
        out["serial"]["us_per_step"] / out["prefetch"]["us_per_step"], 3)
    out["policies"] = policy_ledger(model, topo, mesh_shape, batch, steps,
                                    warmup, cells, cell_config)
    out["boundary"] = boundary_bench(cfg, steps, warmup, cells)
    out["autotune_rankings"] = {
        name: rank_policies(model, topo, name, micro_steps=MICRO,
                            prefetch=True).describe()
        for name in PROFILES
    }
    out["cells"] = cells
    return out


def policy_ledger(model, topo, mesh_shape, batch, steps, warmup, cells,
                  cell_config) -> dict:
    """Predicted-vs-measured per gather policy, on two link profiles.

    Measured: per-stage census wire bytes of the compiled (serial) train
    step, plus its wall time per step.  Predicted:
    core/autotune.predict_traffic with ``upcast_float_collectives=True``
    (the census is compiled for host CPUs, where XLA widens bf16
    collectives to f32).  Modeled times use the un-upcast traffic — the
    real wire cost on each profile.  ``fit_inputs`` is the per-stage
    (tier, α-events, wire bytes) ledger plus the measured time —
    exactly what ``tools/fit_profile.py`` least-squares a per-tier (α, β)
    table from on real hardware.
    """
    ledger = {}
    for label, mcfg_kw in POLICIES:
        kw = dict(prefetch=False)
        kw.update(mcfg_kw)
        mcfg = MiCSConfig(micro_steps=MICRO, **kw)
        engine = CommEngine.from_config(topo, mcfg)
        step = build_train_step(model, topo, mcfg,
                                OptConfig(total_steps=100, warmup_steps=0,
                                          lr_max=3e-3))
        stats = analyze(
            step.lower(init_state_shapes(model),
                       make_batch_shapes(model, MICRO * 8, 32, MICRO))
                .compile().as_text(),
            mesh_shape,
            partition_axes=topo.partition_axes,
            replication_axes=topo.replication_axes)
        state = init_state(model, topo, seed=11)
        _state, timing, _traj = _timed_steps(step, state, batch, steps,
                                             warmup)
        t_measured = timing.median_s
        gp, sp = engine.gather_policy, engine.sync_policy
        predicted = predict_traffic(model, topo, gp, sp, micro_steps=MICRO,
                                    upcast_float_collectives=True)
        cmp = compare_census(predicted["by_stage"], stats["by_stage"])
        wire_pred = predict_traffic(model, topo, gp, sp, micro_steps=MICRO,
                                    profile=get_profile("v5e"))
        byte_match = all(
            abs(row["ratio"] - 1.0) <= 0.02 for row in cmp.values())
        entry = {
            "predicted_vs_measured": cmp,
            "byte_match": byte_match,
            "measured_total_wire_bytes": stats["total_wire_bytes"],
            "measured_us_per_step": round(t_measured * 1e6, 1),
            "modeled_t_comm_us": {},
            "fit_inputs": {
                "t_measured_s": t_measured,
                "stages": {
                    lbl: {
                        "tier": e["tier"],
                        # one (g-1)-hop ring per collective launch (count ==
                        # events for float wires; int8 ships q + scales, so
                        # its launches — and alpha events — double)
                        "alpha_events": (
                            e["events"] * 2 * (e["group_size"] - 1)
                            if lbl == "hop2"
                            else e["count"] * (e["group_size"] - 1)),
                        "wire_bytes": e["wire_bytes"],
                    }
                    for lbl, e in wire_pred["by_stage"].items()
                },
            },
        }
        if gp.carry_offload == "host":
            # The carry's d2h/h2d stream, ledgered exactly as
            # cost_candidate's ``host_offload`` stage prices it: 2 x stack
            # x flat_len bytes per scanned pool per micro-step over the
            # host tier, one α-event per transfer (point-to-point — no
            # ring, so no (g-1) hop factor).
            cb = memplan._COMPUTE_BYTES[gp.wire_dtype]
            scanned = {pl.name for pl in model.pools}
            host_bytes, host_events = 0.0, 0
            for name, (stack, _tp, flat_len) in \
                    model.global_flat_shapes().items():
                if name in scanned and stack > 1:
                    host_bytes += 2.0 * MICRO * stack * flat_len * cb
                    host_events += 2 * MICRO * stack
            entry["fit_inputs"]["stages"]["carry_offload"] = {
                "tier": "host", "alpha_events": host_events,
                "wire_bytes": host_bytes}
            stash_clear()
        for name in PROFILES:
            cand = cost_candidate(model, topo, get_profile(name), gp, sp,
                                  micro_steps=MICRO)
            entry["modeled_t_comm_us"][name] = round(cand.t_comm_s * 1e6, 2)
        ledger[label] = entry
        worst = max(abs(row["ratio"] - 1.0) for row in cmp.values()) \
            if cmp else 0.0
        cells[f"comm/policy/{label}"] = MS.timing_cell(
            cell_config("policy", label, policy=mcfg_kw), timing,
            metrics={
                "measured_total_wire_bytes": stats["total_wire_bytes"],
                "pvm_worst_abs_ratio_err": worst,
                "modeled_t_comm_us": entry["modeled_t_comm_us"],
            },
            ok=byte_match,
            detail=None if byte_match else "census byte mismatch")
    return ledger


def boundary_bench(cfg, steps, warmup, cells) -> dict:
    """The ``BOUNDARY_CELLS`` grid on a replicated mesh (repl=2, p=2, tp=2
    — hop 2 is live).  serial / bucketed / bucketed_offload must produce
    bitwise equal loss/grad-norm trajectories (the offload cell merely
    relocates the carry + AdamW moments to the host stash);
    bucketed_approx pipelines AdamW under hop-2 with a one-bucket-stale
    clip factor, so its trajectory may drift — bounded by
    ``APPROX_CLIP_LOSS_RTOL`` on the final loss.  The ledger records
    per-cell timing stats (median + MAD over the timed steps), the
    bucket-granular hop-2 census, and an ``overlap`` roll-up against the
    link model's exposed-hop-2 prediction per profile; the step-time
    regression gates themselves live in the matrix (variance-aware, vs
    the same-run serial reference)."""
    mesh = make_host_mesh(1, 2, 2, 2)
    topo = MiCSTopology(mesh)
    model = build_model(cfg, tp=2)
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    rng = np.random.default_rng(17)
    b, t = 8, 32
    batch = {
        "tokens": jnp.array(rng.integers(0, cfg.vocab, (MICRO, b, t)),
                            jnp.int32),
        "targets": jnp.array(rng.integers(0, cfg.vocab, (MICRO, b, t)),
                             jnp.int32),
        "mask": jnp.ones((MICRO, b, t), jnp.float32),
    }
    bplan = plan_boundary(model, topo, mode="bucketed",
                          bucket_mb=BOUNDARY_BUCKET_MB)
    out = {"mesh": mesh_shape, "bucket_mb": BOUNDARY_BUCKET_MB,
           "n_buckets": bplan.n_buckets, "steps": steps}

    def cell_config(section, label, **extra):
        return dict(suite="comm", section=section, cell=label,
                    mesh=mesh_shape, model=cfg.name, micro_steps=MICRO,
                    batch=[b, t], steps=steps, warmup=warmup, **extra)
    timings = {}
    for label, cell_kw in BOUNDARY_CELLS:
        mcfg = MiCSConfig(micro_steps=MICRO,
                          hop2_bucket_mb=BOUNDARY_BUCKET_MB, **cell_kw)
        step = build_train_step(model, topo, mcfg,
                                OptConfig(total_steps=100, warmup_steps=0,
                                          lr_max=3e-3))
        stats = analyze(
            step.lower(init_state_shapes(model,
                                         offload_opt=mcfg.offload_opt),
                       make_batch_shapes(model, MICRO * b, t, MICRO))
                .compile().as_text(),
            mesh_shape,
            partition_axes=topo.partition_axes,
            replication_axes=topo.replication_axes)
        state = init_state(model, topo, seed=13,
                           offload_opt=mcfg.offload_opt)
        _state, timing, traj = _timed_steps(step, state, batch, steps,
                                            warmup)
        timings[label] = timing
        out[label] = {
            "us_per_step": round(timing.median_s * 1e6, 1),
            "us_per_step_min": round(timing.min_s * 1e6, 1),
            "trajectory": traj,
            "census_boundary": stats["boundary"],
        }
        if mcfg.offload_opt or mcfg.carry_offload == "host":
            stash_clear()
    out["trajectory_bitwise_equal"] = (
        out["serial"]["trajectory"] == out["bucketed"]["trajectory"])
    out["offload_bitwise_equal"] = (
        out["bucketed"]["trajectory"] == out["bucketed_offload"]["trajectory"])
    exact_final = out["bucketed"]["trajectory"][-1][0]
    approx_final = out["bucketed_approx"]["trajectory"][-1][0]
    out["approx_final_loss_rtol"] = abs(approx_final - exact_final) \
        / abs(exact_final)
    out["measured_exposed_delta_us"] = round(
        out["serial"]["us_per_step"] - out["bucketed"]["us_per_step"], 1)
    sync = CommEngine.from_config(
        topo, MiCSConfig(boundary_schedule="bucketed")).sync_policy
    out["predicted"] = {
        name: {
            "serial": cost_hop2_schedule(
                model, topo, get_profile(name), sync, boundary="serial"),
            "bucketed": cost_hop2_schedule(
                model, topo, get_profile(name), sync, boundary="bucketed",
                bucket_mb=BOUNDARY_BUCKET_MB),
            "bucketed_approx": cost_hop2_schedule(
                model, topo, get_profile(name), sync, boundary="bucketed",
                bucket_mb=BOUNDARY_BUCKET_MB, clip_mode="approx"),
        }
        for name in PROFILES
    }
    # The overlap roll-up: measured step time per cell against the link
    # model's exposed-hop-2 prediction.  The offload cell runs the exact
    # bucketed schedule — its hop-2 prediction is the bucketed row (the
    # host stream is priced separately, cost_candidate's host_offload
    # stage).
    pred_key = {"serial": "serial", "bucketed": "bucketed",
                "bucketed_approx": "bucketed_approx",
                "bucketed_offload": "bucketed"}
    out["overlap"] = {
        label: {
            "us_per_step": out[label]["us_per_step"],
            "us_per_step_min": out[label]["us_per_step_min"],
            "vs_serial": round(out[label]["us_per_step_min"]
                               / out["serial"]["us_per_step_min"], 3),
            "predicted_exposed_hop2_us": {
                name: round(
                    out["predicted"][name][pred_key[label]]["t_exposed_s"]
                    * 1e6, 2)
                for name in PROFILES},
        }
        for label, _ in BOUNDARY_CELLS
    }

    # per-cell contract verdicts (the matrix's contract gates read these)
    def census_ok(label):
        census = out[label]["census_boundary"]
        return census["interleaved"] and census["hop2_ops"] == out["n_buckets"]

    verdicts = {
        "serial": (True, None),
        "bucketed": (
            out["trajectory_bitwise_equal"] and census_ok("bucketed"),
            "bucketed boundary changed numerics or census off-granular"),
        "bucketed_approx": (
            census_ok("bucketed_approx")
            and all(np.isfinite(v)
                    for pair in out["bucketed_approx"]["trajectory"]
                    for v in pair)
            and out["approx_final_loss_rtol"] <= APPROX_CLIP_LOSS_RTOL,
            f"approx clip diverged "
            f"(rtol={out['approx_final_loss_rtol']:.4f})"),
        "bucketed_offload": (
            out["offload_bitwise_equal"] and census_ok("bucketed_offload"),
            "host offload changed numerics or census off-granular"),
    }
    for label, _ in BOUNDARY_CELLS:
        ok, why = verdicts[label]
        cells[f"comm/boundary/{label}"] = MS.timing_cell(
            cell_config("boundary", label, schedule=label,
                        bucket_mb=BOUNDARY_BUCKET_MB,
                        n_buckets=out["n_buckets"]),
            timings[label],
            metrics={
                "hop2_ops": out[label]["census_boundary"]["hop2_ops"],
                "predicted_exposed_hop2_us":
                    out["overlap"][label]["predicted_exposed_hop2_us"],
            },
            ok=ok, detail=None if ok else why)

    # serial keeps a coarse hop-2 (strictly fewer ops than the bucket
    # plan) and the model's exposed-time ordering holds per profile
    pred_ok = out["serial"]["census_boundary"]["hop2_ops"] < out["n_buckets"]
    for name, pred in out["predicted"].items():
        pred_ok &= pred["serial"]["t_exposed_s"] == pred["serial"]["t_total_s"]
        pred_ok &= pred["bucketed"]["t_exposed_s"] \
            <= pred["bucketed"]["t_total_s"]
        pred_ok &= pred["bucketed_approx"]["t_exposed_s"] \
            <= pred["bucketed"]["t_exposed_s"] + 1e-12
    cells["comm/contract/predicted_exposed"] = MS.contract_cell(
        cell_config("contract", "predicted_exposed"), pred_ok,
        detail=None if pred_ok else "exposed-hop2 prediction ordering broke")
    return out


def finish_cells(out: dict) -> None:
    """Post-run contract cells that span sections."""
    host_ok = any(
        s["tier"] == "host"
        for entry in out["policies"].values()
        for s in entry["fit_inputs"]["stages"].values())
    fit_ok = host_ok and all(
        entry["fit_inputs"]["t_measured_s"] > 0
        and entry["fit_inputs"]["stages"]
        for entry in out["policies"].values())
    out["cells"]["comm/contract/host_fit_stage"] = MS.contract_cell(
        dict(suite="comm", section="contract", cell="host_fit_stage"),
        fit_ok,
        detail=None if fit_ok else
        "no host-tier fit stage — tools/fit_profile.py host fit unexercised")


def check_ledger(out: dict, smoke: bool) -> None:
    """The standalone gate shim: apply exactly the matrix's declared gates
    for the ``comm`` suite (contract + variance-aware step-time ratios)."""
    from repro.bench.runner import check_suite

    failures = check_suite("comm", out, smoke=smoke)
    if failures:
        print("comm bench gate FAILED:\n  " + "\n  ".join(failures),
              file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: fewer timing steps, same coverage")
    ap.add_argument("--steps", type=int, default=0,
                    help="timing steps per schedule (default 8, smoke 5)")
    ap.add_argument("--warmup", type=int, default=WARMUP,
                    help="post-compile steps discarded before timing")
    ap.add_argument("--check", action="store_true",
                    help="apply the matrix's comm-suite gates after "
                         "printing the JSON")
    args = ap.parse_args()
    steps = args.steps or (5 if args.smoke else STEPS)
    out = run(steps, args.warmup)
    finish_cells(out)
    print(json.dumps(out, indent=1))
    if args.check:
        check_ledger(out, args.smoke)
