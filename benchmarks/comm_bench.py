"""Serial vs double-buffered-prefetch gather schedules on the host mesh,
plus the autotuner's predicted-vs-measured ledger per gather policy and the
boundary scheduler's serial-vs-bucketed hop-2 ledger.

Run standalone (benchmarks/run.py invokes it as a subprocess so the main
benchmark process keeps its single CPU device):

  PYTHONPATH=src python benchmarks/comm_bench.py [--smoke] [--steps N]

``--smoke`` runs the CI-sized variant (fewer timing steps, same coverage)
— the ci.yml ``bench`` step regression-checks the exposed-hop-2 ledger on
every PR.  Prints one JSON object (saved as BENCH_comm.json by run.py):

* per-schedule wall time per training step, the HLO-census
  gathered-bytes/collective counts, the carried-gather prefetch evidence,
  and the loss trajectories (which must be bitwise equal — the schedules
  differ only in *when* gathers are issued, never in values);
* a ``policies`` section: for each gather/sync policy (flat / inner_first /
  outer_first bf16 wire, inner_first int8, and the qgZ rows shipping the
  int8 block-quantized hop-1 gradient wire), the analytical per-stage wire
  bytes (core/autotune.predict_traffic) against the measured census of the
  compiled step, the α-β modeled comm time under two link profiles (v5e +
  efa-100g, core/linkmodel.py), a measured wall time, and the
  ``fit_inputs`` stage ledger that ``tools/fit_profile.py`` fits per-tier
  (α, β) from;
* a ``boundary`` section on a replicated mesh (hop 2 live): serial vs
  bucketed boundary schedule (core/schedule.py) — bitwise-equal
  loss/grad-norm trajectories, wall times, the census evidence that hop-2
  runs at bucket granularity interleaved with boundary compute, and the
  link model's predicted exposed-vs-hidden hop-2 time per profile;
* the autotuner's full ranked table per profile (``autotune_rankings``) —
  which now ranks ``hop2_bucket_mb`` as a candidate axis.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core.autotune import (
    compare_census, cost_candidate, cost_hop2_schedule, predict_traffic,
    rank_policies,
)
from repro.core.comm import CommEngine
from repro.core.linkmodel import get_profile
from repro.core.mics import (
    MiCSConfig, build_train_step, init_state, init_state_shapes,
    make_batch_shapes,
)
from repro.core.schedule import plan_boundary
from repro.core.topology import MiCSTopology, make_host_mesh
from repro.models.build import build_model
from repro.optim.adamw import OptConfig
from repro.roofline.hlo_stats import analyze

STEPS = 8
MICRO = 2
BOUNDARY_BUCKET_MB = 0.05  # small enough to split the smoke model's pools

PROFILES = ("v5e", "efa-100g")
# (label, MiCSConfig fields) — >= 3 policies for the predicted-vs-measured
# ledger (acceptance criterion of ISSUE 2); the GatherPolicy/SyncPolicy are
# derived via CommEngine.from_config so the ledger prices exactly what the
# step runs.  The qgZ rows ship the int8 hop-1 gradient wire (ISSUE 4).
POLICIES = (
    ("flat@bf16", dict(hierarchical=False)),
    ("inner_first@bf16", dict()),
    ("outer_first@bf16", dict(gather_order="outer_first")),
    ("inner_first@int8", dict(quant_gather=True)),
    ("inner_first@bf16+qgZ", dict(hop1_wire_dtype="int8")),
    ("inner_first@int8+qgZ", dict(quant_gather=True,
                                  hop1_wire_dtype="int8")),
)


def run(steps: int = STEPS) -> dict:
    cfg = smoke_variant(get_config("llama3.2-1b"))
    mesh = make_host_mesh(1, 1, 4, 2)  # p=4 partition group, tp=2
    topo = MiCSTopology(mesh)
    model = build_model(cfg, tp=2)
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))

    rng = np.random.default_rng(5)
    b, t = 8, 32
    batch = {
        "tokens": jnp.array(rng.integers(0, cfg.vocab, (MICRO, b, t)),
                            jnp.int32),
        "targets": jnp.array(rng.integers(0, cfg.vocab, (MICRO, b, t)),
                             jnp.int32),
        "mask": jnp.ones((MICRO, b, t), jnp.float32),
    }

    out = {"mesh": mesh_shape, "partition_size": topo.partition_size,
           "steps": steps, "micro_steps": MICRO}
    for label, prefetch in (("serial", False), ("prefetch", True)):
        mcfg = MiCSConfig(micro_steps=MICRO, prefetch=prefetch)
        step = build_train_step(model, topo, mcfg,
                                OptConfig(total_steps=100, warmup_steps=0,
                                          lr_max=3e-3))
        stats = analyze(
            step.lower(init_state_shapes(model),
                       make_batch_shapes(model, MICRO * b, t, MICRO))
                .compile().as_text(),
            mesh_shape,
            partition_axes=topo.partition_axes,
            replication_axes=topo.replication_axes)
        gather_stages = {k: v for k, v in stats["by_stage"].items()
                         if k.startswith("param_gather")}

        state = init_state(model, topo, seed=11)
        state, m = step(state, batch)  # compile + warm
        jax.block_until_ready(m["loss"])
        losses = []
        t0 = time.perf_counter()
        for _ in range(steps):
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
        dt = (time.perf_counter() - t0) / steps

        out[label] = {
            "us_per_step": round(dt * 1e6, 1),
            "gathered_wire_bytes": sum(
                v["wire_bytes"] for v in gather_stages.values()),
            "param_gather_count": sum(
                v["count"] for v in gather_stages.values()),
            "carried_all_gathers": stats["prefetch"]["carried_all_gathers"],
            "total_wire_bytes": stats["total_wire_bytes"],
            "losses": losses,
        }
    out["loss_bitwise_equal"] = out["serial"]["losses"] \
        == out["prefetch"]["losses"]
    out["speedup"] = round(
        out["serial"]["us_per_step"] / out["prefetch"]["us_per_step"], 3)
    out["policies"] = policy_ledger(model, topo, mesh_shape, batch, steps)
    out["boundary"] = boundary_bench(cfg, steps)
    out["autotune_rankings"] = {
        name: rank_policies(model, topo, name, micro_steps=MICRO,
                            prefetch=True).describe()
        for name in PROFILES
    }
    return out


def policy_ledger(model, topo, mesh_shape, batch, steps) -> dict:
    """Predicted-vs-measured per gather policy, on two link profiles.

    Measured: per-stage census wire bytes of the compiled (serial) train
    step, plus its wall time per step.  Predicted:
    core/autotune.predict_traffic with ``upcast_float_collectives=True``
    (the census is compiled for host CPUs, where XLA widens bf16
    collectives to f32).  Modeled times use the un-upcast traffic — the
    real wire cost on each profile.  ``fit_inputs`` is the per-stage
    (tier, α-events, wire bytes) ledger plus the measured time —
    exactly what ``tools/fit_profile.py`` least-squares a per-tier (α, β)
    table from on real hardware.
    """
    ledger = {}
    for label, mcfg_kw in POLICIES:
        mcfg = MiCSConfig(micro_steps=MICRO, prefetch=False, **mcfg_kw)
        engine = CommEngine.from_config(topo, mcfg)
        step = build_train_step(model, topo, mcfg,
                                OptConfig(total_steps=100, warmup_steps=0,
                                          lr_max=3e-3))
        stats = analyze(
            step.lower(init_state_shapes(model),
                       make_batch_shapes(model, MICRO * 8, 32, MICRO))
                .compile().as_text(),
            mesh_shape,
            partition_axes=topo.partition_axes,
            replication_axes=topo.replication_axes)
        state = init_state(model, topo, seed=11)
        state, m = step(state, batch)  # compile cache warm + donation
        jax.block_until_ready(m["loss"])
        t0 = time.perf_counter()
        for _ in range(steps):
            state, m = step(state, batch)
        jax.block_until_ready(m["loss"])
        t_measured = (time.perf_counter() - t0) / steps
        gp, sp = engine.gather_policy, engine.sync_policy
        predicted = predict_traffic(model, topo, gp, sp, micro_steps=MICRO,
                                    upcast_float_collectives=True)
        cmp = compare_census(predicted["by_stage"], stats["by_stage"])
        wire_pred = predict_traffic(model, topo, gp, sp, micro_steps=MICRO,
                                    profile=get_profile("v5e"))
        entry = {
            "predicted_vs_measured": cmp,
            "byte_match": all(
                abs(row["ratio"] - 1.0) <= 0.02 for row in cmp.values()),
            "measured_total_wire_bytes": stats["total_wire_bytes"],
            "measured_us_per_step": round(t_measured * 1e6, 1),
            "modeled_t_comm_us": {},
            "fit_inputs": {
                "t_measured_s": t_measured,
                "stages": {
                    lbl: {
                        "tier": e["tier"],
                        # one (g-1)-hop ring per collective launch (count ==
                        # events for float wires; int8 ships q + scales, so
                        # its launches — and alpha events — double)
                        "alpha_events": (
                            e["events"] * 2 * (e["group_size"] - 1)
                            if lbl == "hop2"
                            else e["count"] * (e["group_size"] - 1)),
                        "wire_bytes": e["wire_bytes"],
                    }
                    for lbl, e in wire_pred["by_stage"].items()
                },
            },
        }
        for name in PROFILES:
            cand = cost_candidate(model, topo, get_profile(name), gp, sp,
                                  micro_steps=MICRO)
            entry["modeled_t_comm_us"][name] = round(cand.t_comm_s * 1e6, 2)
        ledger[label] = entry
    return ledger


def boundary_bench(cfg, steps) -> dict:
    """Serial vs bucketed boundary schedule on a replicated mesh (repl=2,
    p=2, tp=2 — hop 2 is live).  The two schedules must produce bitwise
    equal loss/grad-norm trajectories; the ledger records wall times, the
    bucket-granular hop-2 census, and the link model's exposed-vs-hidden
    prediction per profile (what a real cluster would regression-check)."""
    mesh = make_host_mesh(1, 2, 2, 2)
    topo = MiCSTopology(mesh)
    model = build_model(cfg, tp=2)
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    rng = np.random.default_rng(17)
    b, t = 8, 32
    batch = {
        "tokens": jnp.array(rng.integers(0, cfg.vocab, (MICRO, b, t)),
                            jnp.int32),
        "targets": jnp.array(rng.integers(0, cfg.vocab, (MICRO, b, t)),
                             jnp.int32),
        "mask": jnp.ones((MICRO, b, t), jnp.float32),
    }
    bplan = plan_boundary(model, topo, mode="bucketed",
                          bucket_mb=BOUNDARY_BUCKET_MB)
    out = {"mesh": mesh_shape, "bucket_mb": BOUNDARY_BUCKET_MB,
           "n_buckets": bplan.n_buckets, "steps": steps}
    for label in ("serial", "bucketed"):
        mcfg = MiCSConfig(micro_steps=MICRO, boundary_schedule=label,
                          hop2_bucket_mb=BOUNDARY_BUCKET_MB)
        step = build_train_step(model, topo, mcfg,
                                OptConfig(total_steps=100, warmup_steps=0,
                                          lr_max=3e-3))
        stats = analyze(
            step.lower(init_state_shapes(model),
                       make_batch_shapes(model, MICRO * b, t, MICRO))
                .compile().as_text(),
            mesh_shape,
            partition_axes=topo.partition_axes,
            replication_axes=topo.replication_axes)
        state = init_state(model, topo, seed=13)
        state, m = step(state, batch)
        jax.block_until_ready(m["loss"])
        traj = []
        t0 = time.perf_counter()
        for _ in range(steps):
            state, m = step(state, batch)
            traj.append((float(m["loss"]), float(m["grad_norm"])))
        dt = (time.perf_counter() - t0) / steps
        out[label] = {
            "us_per_step": round(dt * 1e6, 1),
            "trajectory": traj,
            "census_boundary": stats["boundary"],
        }
    out["trajectory_bitwise_equal"] = (
        out["serial"]["trajectory"] == out["bucketed"]["trajectory"])
    out["measured_exposed_delta_us"] = round(
        out["serial"]["us_per_step"] - out["bucketed"]["us_per_step"], 1)
    sync = CommEngine.from_config(
        topo, MiCSConfig(boundary_schedule="bucketed")).sync_policy
    out["predicted"] = {
        name: {
            "serial": cost_hop2_schedule(
                model, topo, get_profile(name), sync, boundary="serial"),
            "bucketed": cost_hop2_schedule(
                model, topo, get_profile(name), sync, boundary="bucketed",
                bucket_mb=BOUNDARY_BUCKET_MB),
        }
        for name in PROFILES
    }
    return out


def check_ledger(out: dict) -> None:
    """The CI regression gate (ci.yml ``bench`` job): schedules must not
    change numerics, the census must match the analytical model, and the
    exposed-hop-2 / fit ledgers must be present and well-formed."""
    assert out["loss_bitwise_equal"], "prefetch changed the loss"
    b = out["boundary"]
    assert b["trajectory_bitwise_equal"], \
        "bucketed boundary changed the numerics"
    assert b["bucketed"]["census_boundary"]["interleaved"]
    assert b["bucketed"]["census_boundary"]["hop2_ops"] == b["n_buckets"]
    assert b["serial"]["census_boundary"]["hop2_ops"] < b["n_buckets"]
    for name, pred in b["predicted"].items():
        assert pred["serial"]["t_exposed_s"] == pred["serial"]["t_total_s"]
        assert pred["bucketed"]["t_exposed_s"] \
            <= pred["bucketed"]["t_total_s"], name
    for label, entry in out["policies"].items():
        assert entry["byte_match"], (label, "census mismatch")
        assert entry["fit_inputs"]["t_measured_s"] > 0, label
        assert entry["fit_inputs"]["stages"], label


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: fewer timing steps, same coverage")
    ap.add_argument("--steps", type=int, default=0,
                    help="timing steps per schedule (default 8, smoke 2)")
    ap.add_argument("--check", action="store_true",
                    help="assert the ledger invariants (the CI gate) after "
                         "printing the JSON")
    args = ap.parse_args()
    steps = args.steps or (2 if args.smoke else STEPS)
    out = run(steps)
    print(json.dumps(out, indent=1))
    if args.check:
        check_ledger(out)
