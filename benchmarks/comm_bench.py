"""Serial vs double-buffered-prefetch gather schedules on the host mesh,
plus the autotuner's predicted-vs-measured ledger per gather policy and the
boundary scheduler's serial-vs-bucketed hop-2 ledger.

Run standalone (benchmarks/run.py invokes it as a subprocess so the main
benchmark process keeps its single CPU device):

  PYTHONPATH=src python benchmarks/comm_bench.py [--smoke] [--steps N]

``--smoke`` runs the CI-sized variant (fewer timing steps, same coverage)
— the ci.yml ``bench`` step regression-checks the exposed-hop-2 ledger on
every PR.  Prints one JSON object (saved as BENCH_comm.json by run.py):

* per-schedule wall time per training step, the HLO-census
  gathered-bytes/collective counts, the carried-gather prefetch evidence,
  and the loss trajectories (which must be bitwise equal — the schedules
  differ only in *when* gathers are issued, never in values);
* a ``policies`` section: for each gather/sync policy (flat / inner_first /
  outer_first bf16 wire, inner_first int8, and the qgZ rows shipping the
  int8 block-quantized hop-1 gradient wire), the analytical per-stage wire
  bytes (core/autotune.predict_traffic) against the measured census of the
  compiled step, the α-β modeled comm time under two link profiles (v5e +
  efa-100g, core/linkmodel.py), a measured wall time, and the
  ``fit_inputs`` stage ledger that ``tools/fit_profile.py`` fits per-tier
  (α, β) from;
* a ``boundary`` section on a replicated mesh (hop 2 live): serial vs
  bucketed-exact vs bucketed-approx (``clip_mode='approx'``: AdamW
  pipelined under the next bucket's hop-2 with a one-bucket-stale clip
  factor) vs host-offloaded (``carry_offload='host'`` +
  ``offload_opt=True``) boundary cells — exact/offload trajectories
  bitwise equal, approx within ``APPROX_CLIP_LOSS_RTOL``, per-cell
  measured wall times, the bucket-granular hop-2 census, and an
  ``overlap`` roll-up of measured step time vs the link model's predicted
  exposed-hop-2 time per cell and profile;
* the autotuner's full ranked table per profile (``autotune_rankings``) —
  which ranks ``hop2_bucket_mb``, ``clip_mode`` and the host-offloaded
  carry as candidate axes.

The ``--check`` gate additionally fails if any non-serial boundary cell's
measured step time regresses more than ``REGRESSION_FACTOR`` over the
same-run serial reference (CPU io_callback overhead gets its own
documented allowance on the offload cell).
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core.autotune import (
    compare_census, cost_candidate, cost_hop2_schedule, predict_traffic,
    rank_policies,
)
from repro.core import memplan
from repro.core.comm import CommEngine
from repro.core.hostoffload import stash_clear
from repro.core.linkmodel import get_profile
from repro.core.mics import (
    MiCSConfig, build_train_step, init_state, init_state_shapes,
    make_batch_shapes,
)
from repro.core.schedule import APPROX_CLIP_LOSS_RTOL, plan_boundary
from repro.core.topology import MiCSTopology, make_host_mesh
from repro.models.build import build_model
from repro.optim.adamw import OptConfig
from repro.roofline.hlo_stats import analyze

STEPS = 8
MICRO = 2
BOUNDARY_BUCKET_MB = 0.05  # small enough to split the smoke model's pools

# --check step-time gate: each non-serial boundary cell's fastest timed step
# vs the same-run serial reference (the min over steps is the noise-robust
# statistic on a shared CI host).  The offload cell gets a wider allowance:
# on the CPU backend every d2h/h2d stream is a synchronous Python
# io_callback round-trip, an overhead a real DMA engine does not pay.
REGRESSION_FACTOR = 1.2
OFFLOAD_REGRESSION_FACTOR = 3.0

PROFILES = ("v5e", "efa-100g")
# (label, MiCSConfig fields) — >= 3 policies for the predicted-vs-measured
# ledger (acceptance criterion of ISSUE 2); the GatherPolicy/SyncPolicy are
# derived via CommEngine.from_config so the ledger prices exactly what the
# step runs.  The qgZ rows ship the int8 hop-1 gradient wire (ISSUE 4);
# the +host row streams the prefetch carry over the host tier, giving
# tools/fit_profile.py a ``tier='host'`` stage to constrain (α, β) from.
POLICIES = (
    ("flat@bf16", dict(hierarchical=False)),
    ("inner_first@bf16", dict()),
    ("outer_first@bf16", dict(gather_order="outer_first")),
    ("inner_first@int8", dict(quant_gather=True)),
    ("inner_first@bf16+qgZ", dict(hop1_wire_dtype="int8")),
    ("inner_first@int8+qgZ", dict(quant_gather=True,
                                  hop1_wire_dtype="int8")),
    ("inner_first@bf16+host", dict(prefetch=True, carry_offload="host")),
    # second host row at a different bytes-per-event ratio (fp32 carry is
    # 2x the bytes of bf16 at the same event count) — separates the host
    # α from its β in the fit
    ("inner_first@fp32+host", dict(prefetch=True, gather_dtype="float32",
                                   carry_offload="host")),
)

# Boundary cells (replicated mesh): the bitwise-exact schedules, the
# approximate-clip pipeline, and the host-offloaded cell (carry + AdamW
# moments streamed through the host stash; numerics still bitwise-exact).
BOUNDARY_CELLS = (
    ("serial", dict(boundary_schedule="serial")),
    ("bucketed", dict(boundary_schedule="bucketed")),
    ("bucketed_approx", dict(boundary_schedule="bucketed",
                             clip_mode="approx")),
    ("bucketed_offload", dict(boundary_schedule="bucketed",
                              carry_offload="host", offload_opt=True)),
)


def run(steps: int = STEPS) -> dict:
    cfg = smoke_variant(get_config("llama3.2-1b"))
    mesh = make_host_mesh(1, 1, 4, 2)  # p=4 partition group, tp=2
    topo = MiCSTopology(mesh)
    model = build_model(cfg, tp=2)
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))

    rng = np.random.default_rng(5)
    b, t = 8, 32
    batch = {
        "tokens": jnp.array(rng.integers(0, cfg.vocab, (MICRO, b, t)),
                            jnp.int32),
        "targets": jnp.array(rng.integers(0, cfg.vocab, (MICRO, b, t)),
                             jnp.int32),
        "mask": jnp.ones((MICRO, b, t), jnp.float32),
    }

    out = {"mesh": mesh_shape, "partition_size": topo.partition_size,
           "steps": steps, "micro_steps": MICRO}
    for label, prefetch in (("serial", False), ("prefetch", True)):
        mcfg = MiCSConfig(micro_steps=MICRO, prefetch=prefetch)
        step = build_train_step(model, topo, mcfg,
                                OptConfig(total_steps=100, warmup_steps=0,
                                          lr_max=3e-3))
        stats = analyze(
            step.lower(init_state_shapes(model),
                       make_batch_shapes(model, MICRO * b, t, MICRO))
                .compile().as_text(),
            mesh_shape,
            partition_axes=topo.partition_axes,
            replication_axes=topo.replication_axes)
        gather_stages = {k: v for k, v in stats["by_stage"].items()
                         if k.startswith("param_gather")}

        state = init_state(model, topo, seed=11)
        state, m = step(state, batch)  # compile + warm
        jax.block_until_ready(m["loss"])
        losses = []
        t0 = time.perf_counter()
        for _ in range(steps):
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
        dt = (time.perf_counter() - t0) / steps

        out[label] = {
            "us_per_step": round(dt * 1e6, 1),
            "gathered_wire_bytes": sum(
                v["wire_bytes"] for v in gather_stages.values()),
            "param_gather_count": sum(
                v["count"] for v in gather_stages.values()),
            "carried_all_gathers": stats["prefetch"]["carried_all_gathers"],
            "total_wire_bytes": stats["total_wire_bytes"],
            "losses": losses,
        }
    out["loss_bitwise_equal"] = out["serial"]["losses"] \
        == out["prefetch"]["losses"]
    out["speedup"] = round(
        out["serial"]["us_per_step"] / out["prefetch"]["us_per_step"], 3)
    out["policies"] = policy_ledger(model, topo, mesh_shape, batch, steps)
    out["boundary"] = boundary_bench(cfg, steps)
    out["autotune_rankings"] = {
        name: rank_policies(model, topo, name, micro_steps=MICRO,
                            prefetch=True).describe()
        for name in PROFILES
    }
    return out


def policy_ledger(model, topo, mesh_shape, batch, steps) -> dict:
    """Predicted-vs-measured per gather policy, on two link profiles.

    Measured: per-stage census wire bytes of the compiled (serial) train
    step, plus its wall time per step.  Predicted:
    core/autotune.predict_traffic with ``upcast_float_collectives=True``
    (the census is compiled for host CPUs, where XLA widens bf16
    collectives to f32).  Modeled times use the un-upcast traffic — the
    real wire cost on each profile.  ``fit_inputs`` is the per-stage
    (tier, α-events, wire bytes) ledger plus the measured time —
    exactly what ``tools/fit_profile.py`` least-squares a per-tier (α, β)
    table from on real hardware.
    """
    ledger = {}
    for label, mcfg_kw in POLICIES:
        kw = dict(prefetch=False)
        kw.update(mcfg_kw)
        mcfg = MiCSConfig(micro_steps=MICRO, **kw)
        engine = CommEngine.from_config(topo, mcfg)
        step = build_train_step(model, topo, mcfg,
                                OptConfig(total_steps=100, warmup_steps=0,
                                          lr_max=3e-3))
        stats = analyze(
            step.lower(init_state_shapes(model),
                       make_batch_shapes(model, MICRO * 8, 32, MICRO))
                .compile().as_text(),
            mesh_shape,
            partition_axes=topo.partition_axes,
            replication_axes=topo.replication_axes)
        state = init_state(model, topo, seed=11)
        state, m = step(state, batch)  # compile cache warm + donation
        jax.block_until_ready(m["loss"])
        t0 = time.perf_counter()
        for _ in range(steps):
            state, m = step(state, batch)
        jax.block_until_ready(m["loss"])
        t_measured = (time.perf_counter() - t0) / steps
        gp, sp = engine.gather_policy, engine.sync_policy
        predicted = predict_traffic(model, topo, gp, sp, micro_steps=MICRO,
                                    upcast_float_collectives=True)
        cmp = compare_census(predicted["by_stage"], stats["by_stage"])
        wire_pred = predict_traffic(model, topo, gp, sp, micro_steps=MICRO,
                                    profile=get_profile("v5e"))
        entry = {
            "predicted_vs_measured": cmp,
            "byte_match": all(
                abs(row["ratio"] - 1.0) <= 0.02 for row in cmp.values()),
            "measured_total_wire_bytes": stats["total_wire_bytes"],
            "measured_us_per_step": round(t_measured * 1e6, 1),
            "modeled_t_comm_us": {},
            "fit_inputs": {
                "t_measured_s": t_measured,
                "stages": {
                    lbl: {
                        "tier": e["tier"],
                        # one (g-1)-hop ring per collective launch (count ==
                        # events for float wires; int8 ships q + scales, so
                        # its launches — and alpha events — double)
                        "alpha_events": (
                            e["events"] * 2 * (e["group_size"] - 1)
                            if lbl == "hop2"
                            else e["count"] * (e["group_size"] - 1)),
                        "wire_bytes": e["wire_bytes"],
                    }
                    for lbl, e in wire_pred["by_stage"].items()
                },
            },
        }
        if gp.carry_offload == "host":
            # The carry's d2h/h2d stream, ledgered exactly as
            # cost_candidate's ``host_offload`` stage prices it: 2 x stack
            # x flat_len bytes per scanned pool per micro-step over the
            # host tier, one α-event per transfer (point-to-point — no
            # ring, so no (g-1) hop factor).
            cb = memplan._COMPUTE_BYTES[gp.wire_dtype]
            scanned = {pl.name for pl in model.pools}
            host_bytes, host_events = 0.0, 0
            for name, (stack, _tp, flat_len) in \
                    model.global_flat_shapes().items():
                if name in scanned and stack > 1:
                    host_bytes += 2.0 * MICRO * stack * flat_len * cb
                    host_events += 2 * MICRO * stack
            entry["fit_inputs"]["stages"]["carry_offload"] = {
                "tier": "host", "alpha_events": host_events,
                "wire_bytes": host_bytes}
            stash_clear()
        for name in PROFILES:
            cand = cost_candidate(model, topo, get_profile(name), gp, sp,
                                  micro_steps=MICRO)
            entry["modeled_t_comm_us"][name] = round(cand.t_comm_s * 1e6, 2)
        ledger[label] = entry
    return ledger


def boundary_bench(cfg, steps) -> dict:
    """The ``BOUNDARY_CELLS`` grid on a replicated mesh (repl=2, p=2, tp=2
    — hop 2 is live).  serial / bucketed / bucketed_offload must produce
    bitwise equal loss/grad-norm trajectories (the offload cell merely
    relocates the carry + AdamW moments to the host stash);
    bucketed_approx pipelines AdamW under hop-2 with a one-bucket-stale
    clip factor, so its trajectory may drift — bounded by
    ``APPROX_CLIP_LOSS_RTOL`` on the final loss.  The ledger records
    per-cell wall times (mean and min over the timed steps), the
    bucket-granular hop-2 census, and an ``overlap`` roll-up against the
    link model's exposed-hop-2 prediction per profile (what a real cluster
    would regression-check)."""
    mesh = make_host_mesh(1, 2, 2, 2)
    topo = MiCSTopology(mesh)
    model = build_model(cfg, tp=2)
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    rng = np.random.default_rng(17)
    b, t = 8, 32
    batch = {
        "tokens": jnp.array(rng.integers(0, cfg.vocab, (MICRO, b, t)),
                            jnp.int32),
        "targets": jnp.array(rng.integers(0, cfg.vocab, (MICRO, b, t)),
                             jnp.int32),
        "mask": jnp.ones((MICRO, b, t), jnp.float32),
    }
    bplan = plan_boundary(model, topo, mode="bucketed",
                          bucket_mb=BOUNDARY_BUCKET_MB)
    out = {"mesh": mesh_shape, "bucket_mb": BOUNDARY_BUCKET_MB,
           "n_buckets": bplan.n_buckets, "steps": steps}
    for label, cell_kw in BOUNDARY_CELLS:
        mcfg = MiCSConfig(micro_steps=MICRO,
                          hop2_bucket_mb=BOUNDARY_BUCKET_MB, **cell_kw)
        step = build_train_step(model, topo, mcfg,
                                OptConfig(total_steps=100, warmup_steps=0,
                                          lr_max=3e-3))
        stats = analyze(
            step.lower(init_state_shapes(model,
                                         offload_opt=mcfg.offload_opt),
                       make_batch_shapes(model, MICRO * b, t, MICRO))
                .compile().as_text(),
            mesh_shape,
            partition_axes=topo.partition_axes,
            replication_axes=topo.replication_axes)
        state = init_state(model, topo, seed=13,
                           offload_opt=mcfg.offload_opt)
        state, m = step(state, batch)
        jax.block_until_ready(m["loss"])
        traj = []
        times = []
        for _ in range(steps):
            t0 = time.perf_counter()
            state, m = step(state, batch)
            # float() blocks on the step, so per-step times are honest
            traj.append((float(m["loss"]), float(m["grad_norm"])))
            times.append(time.perf_counter() - t0)
        out[label] = {
            "us_per_step": round(sum(times) / len(times) * 1e6, 1),
            "us_per_step_min": round(min(times) * 1e6, 1),
            "trajectory": traj,
            "census_boundary": stats["boundary"],
        }
        if mcfg.offload_opt or mcfg.carry_offload == "host":
            stash_clear()
    out["trajectory_bitwise_equal"] = (
        out["serial"]["trajectory"] == out["bucketed"]["trajectory"])
    out["offload_bitwise_equal"] = (
        out["bucketed"]["trajectory"] == out["bucketed_offload"]["trajectory"])
    exact_final = out["bucketed"]["trajectory"][-1][0]
    approx_final = out["bucketed_approx"]["trajectory"][-1][0]
    out["approx_final_loss_rtol"] = abs(approx_final - exact_final) \
        / abs(exact_final)
    out["measured_exposed_delta_us"] = round(
        out["serial"]["us_per_step"] - out["bucketed"]["us_per_step"], 1)
    sync = CommEngine.from_config(
        topo, MiCSConfig(boundary_schedule="bucketed")).sync_policy
    out["predicted"] = {
        name: {
            "serial": cost_hop2_schedule(
                model, topo, get_profile(name), sync, boundary="serial"),
            "bucketed": cost_hop2_schedule(
                model, topo, get_profile(name), sync, boundary="bucketed",
                bucket_mb=BOUNDARY_BUCKET_MB),
            "bucketed_approx": cost_hop2_schedule(
                model, topo, get_profile(name), sync, boundary="bucketed",
                bucket_mb=BOUNDARY_BUCKET_MB, clip_mode="approx"),
        }
        for name in PROFILES
    }
    # The overlap roll-up: measured step time per cell against the link
    # model's exposed-hop-2 prediction.  The offload cell runs the exact
    # bucketed schedule — its hop-2 prediction is the bucketed row (the
    # host stream is priced separately, cost_candidate's host_offload
    # stage).
    pred_key = {"serial": "serial", "bucketed": "bucketed",
                "bucketed_approx": "bucketed_approx",
                "bucketed_offload": "bucketed"}
    out["overlap"] = {
        label: {
            "us_per_step": out[label]["us_per_step"],
            "us_per_step_min": out[label]["us_per_step_min"],
            "vs_serial": round(out[label]["us_per_step_min"]
                               / out["serial"]["us_per_step_min"], 3),
            "predicted_exposed_hop2_us": {
                name: round(
                    out["predicted"][name][pred_key[label]]["t_exposed_s"]
                    * 1e6, 2)
                for name in PROFILES},
        }
        for label, _ in BOUNDARY_CELLS
    }
    return out


def check_ledger(out: dict) -> None:
    """The CI regression gate (ci.yml ``bench`` job): schedules must not
    change numerics, the census must match the analytical model, and the
    exposed-hop-2 / fit ledgers must be present and well-formed."""
    assert out["loss_bitwise_equal"], "prefetch changed the loss"
    b = out["boundary"]
    assert b["trajectory_bitwise_equal"], \
        "bucketed boundary changed the numerics"
    assert b["offload_bitwise_equal"], \
        "host offload changed the numerics"
    for label in ("bucketed", "bucketed_approx", "bucketed_offload"):
        census = b[label]["census_boundary"]
        assert census["interleaved"], label
        assert census["hop2_ops"] == b["n_buckets"], label
    assert b["serial"]["census_boundary"]["hop2_ops"] < b["n_buckets"]
    assert all(np.isfinite(v) for pair in b["bucketed_approx"]["trajectory"]
               for v in pair), "approx clip diverged"
    assert b["approx_final_loss_rtol"] <= APPROX_CLIP_LOSS_RTOL, \
        b["approx_final_loss_rtol"]
    for name, pred in b["predicted"].items():
        assert pred["serial"]["t_exposed_s"] == pred["serial"]["t_total_s"]
        assert pred["bucketed"]["t_exposed_s"] \
            <= pred["bucketed"]["t_total_s"], name
        assert pred["bucketed_approx"]["t_exposed_s"] \
            <= pred["bucketed"]["t_exposed_s"] + 1e-12, name
    # Step-time regression gate: non-serial cells vs the same-run serial
    # reference (min over timed steps; offload pays documented CPU
    # io_callback overhead, hence its wider factor).
    ref_us = b["serial"]["us_per_step_min"]
    for label, _ in BOUNDARY_CELLS[1:]:
        factor = (OFFLOAD_REGRESSION_FACTOR if "offload" in label
                  else REGRESSION_FACTOR)
        assert b[label]["us_per_step_min"] <= factor * ref_us, (
            label, b[label]["us_per_step_min"], ref_us, factor)
    for label, entry in out["policies"].items():
        assert entry["byte_match"], (label, "census mismatch")
        assert entry["fit_inputs"]["t_measured_s"] > 0, label
        assert entry["fit_inputs"]["stages"], label
    assert any(
        s["tier"] == "host"
        for entry in out["policies"].values()
        for s in entry["fit_inputs"]["stages"].values()), \
        "no host-tier fit stage — tools/fit_profile.py host fit unexercised"


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: fewer timing steps, same coverage")
    ap.add_argument("--steps", type=int, default=0,
                    help="timing steps per schedule (default 8, smoke 2)")
    ap.add_argument("--check", action="store_true",
                    help="assert the ledger invariants (the CI gate) after "
                         "printing the JSON")
    args = ap.parse_args()
    steps = args.steps or (2 if args.smoke else STEPS)
    out = run(steps)
    print(json.dumps(out, indent=1))
    if args.check:
        check_ledger(out)
