"""Benchmark harness — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (us_per_call = modeled step/op time
in microseconds where applicable) and writes artifacts/benchmarks/*.json.

Measured-on-CPU quantities (kernel wall times, fidelity loss curves) run
here; cluster-scale quantities are derived from (a) the calibrated alpha-beta
model of the paper's AWS environment (benchmarks/paper_model.py) and (b) the
compiled-HLO statistics cached by the multi-pod dry-run
(artifacts/dryrun/*.json).  Nothing pretends to be a wall-clock TPU
measurement; EXPERIMENTS.md labels every number's provenance.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import time

from benchmarks import paper_model as pm

ART = pathlib.Path(__file__).resolve().parent.parent / "artifacts"
OUT = ART / "benchmarks"

ROWS: list[tuple[str, float, str]] = []
RESULTS: dict[str, object] = {}


def emit(name: str, us: float, derived: str):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.3f},{derived}")


# ---------------------------------------------------------------------------
# Fig 2 — effective all-gather bandwidth vs scale and message size
# ---------------------------------------------------------------------------

def bench_fig2_effective_bandwidth():
    table = {}
    for nodes in (2, 4, 8, 16, 32):
        g = nodes * 8
        for mb in (32, 128, 512, 1024):
            b = pm.effective_bandwidth(pm.NET_100G, g, mb * 1e6) / 1e9
            table[f"{nodes}n_{mb}MB"] = round(b, 2)
    RESULTS["fig2"] = table
    small = table["32n_32MB"]
    big = table["2n_1024MB"]
    emit("fig2_effective_bandwidth",
         pm.t_all_gather(pm.NET_100G, 64, 128e6) * 1e6,
         f"32n@32MB={small}GBps vs 2n@1GB={big}GBps vs intra-node "
         f"{pm.effective_bandwidth(pm.NET_100G, 8, 1e9)/1e9:.0f}GBps "
         f"(paper: 128 intra, ~11 at 64 GPUs, worse for small msgs)")
    assert small < big < 128


# ---------------------------------------------------------------------------
# Fig 7/8 — strong scaling on 100 Gbps; Fig 9 TFLOPS
# ---------------------------------------------------------------------------

WORKLOADS = {
    "bert-10b": (10e9, 127, 8),
    "bert-15b": (15e9, 190, 16),
    "bert-20b": (20e9, 64, 16),
    "bert-50b": (50e9, 62, 64),
    "roberta-20b": (20e9, 62, 16),
    "gpt2-20b": (20e9, 62, 16),
}


def bench_fig7_8_scaling():
    out = {}
    best = 0.0
    for name, (n_params, layers, p) in WORKLOADS.items():
        w = pm.bert_workload(name, n_params, layers)
        rows = []
        for n in (16, 32, 64, 128):
            if n < p:
                rows.append(None)
                continue
            t_m = pm.step_time(w, pm.NET_100G, n, p)
            t_d = pm.step_time(w, pm.NET_100G, n, p, system="zero3",
                               coalesced=False, fine_sync=False)
            rows.append({
                "n": n,
                "mics_samples_s": round(n * 32 / t_m, 1),
                "deepspeed_samples_s": round(n * 32 / t_d, 1),
                "ratio": round(t_d / t_m, 2),
            })
            best = max(best, t_d / t_m)
        valid = [r for r in rows if r]
        base = valid[0]
        eff = (valid[-1]["mics_samples_s"] / valid[-1]["n"]) / \
              (base["mics_samples_s"] / base["n"])
        out[name] = {"rows": rows, "scaling_efficiency": round(eff, 3)}
        emit(f"fig7_{name}",
             pm.step_time(w, pm.NET_100G, max(p, 16), p) * 1e6,
             f"MiCS/DS up to {max(r['ratio'] for r in valid):.2f}x, "
             f"strong-scaling eff {eff:.3f}")
    RESULTS["fig7_8"] = out
    emit("fig7_8_max_ratio", 0.0,
         f"max modeled MiCS/DeepSpeed={best:.2f}x (paper reports up to 2.89x)")


def bench_fig9_tflops():
    out = {}
    for name, (n_params, layers, p) in WORKLOADS.items():
        w = pm.bert_workload(name, n_params, layers)
        n = max(p, 64)
        t = pm.step_time(w, pm.NET_100G, n, p)
        flops_gpu = 32 * w.flops_per_sample * (6 / 8) / t  # useful 6ND
        out[name] = round(flops_gpu / 1e12, 1)
        emit(f"fig9_tflops_{name}", t * 1e6,
             f"{flops_gpu/1e12:.0f} TFLOPS/GPU "
             f"({flops_gpu/pm.V100_PEAK*100:.0f}% of V100 peak; "
             f"paper: 42% for 10B)")
    RESULTS["fig9"] = out


# ---------------------------------------------------------------------------
# Fig 10 — 400 Gbps A100 cluster; §5.1.5 — 100B case study at 512
# ---------------------------------------------------------------------------

def bench_fig10_400g():
    out = {}
    for name in ("bert-15b", "bert-20b"):
        n_params, layers, p = WORKLOADS[name]
        w = pm.bert_workload(name, n_params, layers)
        ratios = []
        for n in (16, 32, 64):
            t_m = pm.step_time(w, pm.NET_400G, n, p, peak=312e12)
            t_d = pm.step_time(w, pm.NET_400G, n, p, system="zero3",
                               coalesced=False, fine_sync=False, peak=312e12)
            ratios.append(round(t_d / t_m, 2))
        out[name] = ratios
        emit(f"fig10_{name}", 0.0,
             f"MiCS/DS at 16/32/64 A100s: {ratios} (paper: up to 2.21x, "
             f"gap narrows vs 100Gbps)")
    RESULTS["fig10"] = out


def bench_case_study_100b():
    w = dataclasses.replace(
        pm.bert_workload("100b", 100e9, 80, seq=2048), micro_batch=16)
    rows = {}
    for n in (128, 512):
        t = pm.step_time(w, pm.NET_400G, n, 128, peak=312e12, eff=0.57)
        tf = w.micro_batch * w.micro_steps * w.flops_per_sample * (6 / 8) \
            / t / 1e12
        rows[n] = round(tf, 1)
    eff = rows[512] / rows[128]
    hw = round(rows[512] * 8 / 6, 1)  # incl. activation recompute, as the
    # paper reports ("with activation checkpointing")
    RESULTS["case_study_100b"] = {"useful_tflops": rows,
                                  "hardware_tflops_512": hw,
                                  "weak_scaling": round(eff, 4)}
    emit("case_study_100b", 0.0,
         f"modeled {hw:.0f} hardware TFLOPS/GPU at 512 "
         f"({rows[512]:.0f} useful 6ND), weak scaling {eff:.3f} "
         f"(paper: 170-179 TFLOPS incl. recompute, 0.994). DeepSpeed's "
         f"measured collapse to 62 TFLOPS is allocator/fragmentation-driven "
         f"and outside an alpha-beta model — recorded as a deviation.")


# ---------------------------------------------------------------------------
# Fig 12 — partition-group size ablation (dry-run artifacts + model)
# ---------------------------------------------------------------------------

def _dryrun_records(tag=""):
    recs = []
    for p in sorted((ART / "dryrun").glob("*.json")):
        r = json.loads(p.read_text())
        if (r.get("tag") or "") == tag:
            recs.append(r)
    return recs


def bench_fig12_partition_group():
    # analytic (paper environment)
    w = pm.bert_workload("bert-10b", 10e9, 127)
    th = {p: round(pm.throughput(w, pm.NET_100G, 64, p), 1)
          for p in (8, 16, 32, 64)}
    RESULTS["fig12_model"] = th
    ratio = th[8] / th[64]
    emit("fig12_partition_group", 0.0,
         f"throughput p=8 vs p=64 on 64 GPUs: {ratio:.2f}x (paper: 1.6x)")

    # HLO-derived (TPU dry-run ablation artifacts, if generated)
    cells = [r for p in sorted((ART / "dryrun").glob("*fig12*.json"))
             for r in [json.loads(p.read_text())]
             if r["shape"] == "train_4k"]
    if cells:
        by_p = {r["partition_size"]:
                r["stats"]["total_wire_bytes"] for r in sorted(
                    cells, key=lambda r: r["partition_size"])}
        RESULTS["fig12_hlo_wire_bytes"] = by_p
        emit("fig12_hlo", 0.0,
             "wire bytes by p: " + str({k: f"{v:.2e}" for k, v in by_p.items()}))


# ---------------------------------------------------------------------------
# Fig 11 — Megatron-LM-3D comparison (modeled)
# ---------------------------------------------------------------------------

def bench_fig11_megatron():
    """Paper §5.1.3: 128-layer BERT-10B-wide model, 64 GPUs, micro 8,
    global 4096 (s=8).  Megatron-3D step = pipeline-bubbled compute + TP
    activation all-reduces + DP gradient all-reduce; the bubble fraction is
    (p_stages-1)/(m+p_stages-1)."""
    n = 64
    n_params = 10e9
    layers = 128
    w = dataclasses.replace(pm.bert_workload("bert-10b-128L", n_params, layers),
                            micro_steps=8)
    s, mb = w.micro_steps, w.micro_batch
    t_comp = s * mb * w.flops_per_sample / (pm.V100_PEAK * pm.V100_EFF)

    def megatron(tp, pp):
        dp = n // (tp * pp)
        micros = s * dp  # microbatches filling the pipeline per step
        bubble = (pp - 1) / (micros + pp - 1)
        comp = t_comp / 1.0  # same per-GPU compute (model split over tp*pp,
        # data over dp -> per-GPU work constant at fixed n)
        # TP all-reduces: 4 per layer-pass (fwd+bwd) on activations
        act_bytes = mb * 512 * 2560 * 2
        t_tp = 0.0
        if tp > 1:
            per = pm.t_all_reduce(pm.NET_100G, tp, act_bytes)
            t_tp = 4 * (layers / pp) * s * per * 2
        # DP gradient all-reduce at the boundary
        t_dp = pm.t_all_reduce(pm.NET_100G, dp, 2 * n_params / (tp * pp)) \
            if dp > 1 else 0.0
        return (comp + t_tp + t_dp) / (1 - bubble)

    t_cfg = {f"tp{tp}_pp{pp}": megatron(tp, pp)
             for tp, pp in ((8, 1), (4, 4), (2, 8))}
    t_mics = pm.step_time(w, pm.NET_100G, n, 8)
    best = min(t_cfg.values())
    worst = max(t_cfg.values())
    RESULTS["fig11"] = {
        "megatron_steps_s": {k: round(v, 1) for k, v in t_cfg.items()},
        "mics_step_s": round(t_mics, 1),
        "mics_vs_best_megatron": round(best / t_mics, 2),
        "megatron_config_spread": round(worst / best, 2),
    }
    emit("fig11_megatron3d", t_mics * 1e6,
         f"MiCS vs best Megatron-3D config: {best/t_mics:.2f}x (paper: up "
         f"to 1.31x); Megatron config spread {worst/best:.2f}x (paper: 1.38x)"
         f" — direction + sensitivity reproduced; the alpha-beta model ranks"
         f" tp8pp1 best while the paper measured tp2pp8 (their TP-sync"
         f" overheads exceed the pure-bandwidth cost)")


# ---------------------------------------------------------------------------
# Fig 13 — hierarchical all-gather
# ---------------------------------------------------------------------------

def bench_fig13_hierarchical():
    # micro-benchmark analogue: 2 nodes, 16 GPUs, varying message size
    out = {}
    for mb in (32, 64, 128, 256):
        m = mb * 1e6
        t_van = pm.t_all_gather(pm.NET_100G, 16, m)
        t_hier = pm.t_hier_all_gather(pm.NET_100G, 16, m)
        out[f"{mb}MB"] = round(t_hier / t_van, 3)
    RESULTS["fig13_time_ratio"] = out
    emit("fig13_hierarchical_micro",
         pm.t_all_gather(pm.NET_100G, 16, 128e6) * 1e6,
         f"hier/vanilla time at 128MB: {out['128MB']:.2f} (paper: 0.721)")
    # exact volume law: inter-node bytes drop from (p-1)M/p to (p-k)M/p
    for p, k in ((16, 8), (32, 8), (64, 8)):
        red = 1 - (p - k) / (p - 1)
        emit(f"fig13_volume_law_p{p}", 0.0,
             f"inter-node traffic reduced {red:.1%} "
             f"(paper: 11.1-46.6% for 8<=p<=64)")


# ---------------------------------------------------------------------------
# Fig 14 — 2-hop gradient synchronization
# ---------------------------------------------------------------------------

def bench_fig14_two_hop():
    w = pm.bert_workload("bert-10b", 10e9, 127)
    out = {}
    for n in (32, 64, 128):
        t_2hop = pm.step_time(w, pm.NET_100G, n, 8)
        t_alt = pm.step_time(w, pm.NET_100G, n, 8, system="mics_alt")
        out[n] = round(t_alt / t_2hop - 1, 3)
    RESULTS["fig14"] = out
    emit("fig14_two_hop", 0.0,
         f"2-hop improvement vs alternative schedule at 32/64/128 GPUs: "
         f"{[f'{v:+.1%}' for v in out.values()]} (paper: 11-24.9%)")
    # analytic lower bound from §3.4: C_alt/C_2hop >= 2s/(s + 2) at equal BW
    s = 4
    emit("fig14_lower_bound", 0.0,
         f"paper's s=4 equal-bandwidth bound: {2*s/(s+2):.3f}x (>=25% gain)")


# ---------------------------------------------------------------------------
# Fig 15 — implementation optimizations (coalesced gathers, fine sync)
# ---------------------------------------------------------------------------

def bench_fig15_impl_opts():
    w = pm.bert_workload("bert-10b", 10e9, 127)
    out = {}
    for n in (32, 64, 128):
        t_ds = pm.step_time(w, pm.NET_100G, n, n, system="zero3",
                            coalesced=False, fine_sync=False)
        t_mz = pm.step_time(w, pm.NET_100G, n, n, system="zero3")
        t_m = pm.step_time(w, pm.NET_100G, n, 8)
        out[n] = {"mics_zero3_vs_ds": round(t_ds / t_mz - 1, 3),
                  "mics_vs_mics_zero3": round(t_mz / t_m, 2)}
    RESULTS["fig15"] = out
    emit("fig15_impl_opts", 0.0,
         f"MiCS(ZeRO-3) vs DeepSpeed at 128: "
         f"{out[128]['mics_zero3_vs_ds']:+.1%} (paper: +54.1%); "
         f"full MiCS another {out[128]['mics_vs_mics_zero3']:.2f}x on top")
    # structural fact from the flat-pool implementation:
    from repro.configs import get_config
    from repro.models.build import build_model
    model = build_model(get_config("granite-8b"), tp=16)
    segs = len(model.pool("layers").layout.segments)
    emit("fig15_coalescing_factor", 0.0,
         f"flat pools turn {segs} per-layer tensors into 1 gather "
         f"({segs}x fewer collectives than per-tensor fetching)")


# ---------------------------------------------------------------------------
# Fig 16 — fidelity (real CPU training, synthetic corpus)
# ---------------------------------------------------------------------------

def bench_fig16_fidelity():
    import jax.numpy as jnp

    from repro.configs import get_config, smoke_variant
    from repro.core.mics import MiCSConfig, build_train_step, init_state
    from repro.core.topology import MiCSTopology, make_host_mesh
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.models.build import build_model
    from repro.optim.adamw import OptConfig

    cfg = smoke_variant(get_config("llama3.2-1b"))
    topo = MiCSTopology(make_host_mesh(1, 1, 1, 1))
    model = build_model(cfg, tp=1)
    dc = DataConfig(vocab=cfg.vocab, seq=64, global_batch=8, micro_steps=2)
    src = SyntheticLM(dc)

    curves = {}
    for label, mcfg in (("2hop", MiCSConfig(micro_steps=2)),
                        ("alternative", MiCSConfig(micro_steps=2,
                                                   sync_mode="allreduce_slice"))):
        state = init_state(model, topo, seed=9)
        step = build_train_step(model, topo, mcfg,
                                OptConfig(total_steps=40, warmup_steps=2,
                                          lr_max=2e-3))
        losses = []
        t0 = time.time()
        for i in range(30):
            batch = {k: jnp.asarray(v) for k, v in
                     src.global_step_batch(i).items()}
            state, metrics = step(state, batch)
            losses.append(round(float(metrics["loss"]), 4))
        dt = (time.time() - t0) / 30
        curves[label] = losses
    RESULTS["fig16"] = curves
    gap = max(abs(a - b) for a, b in zip(curves["2hop"],
                                         curves["alternative"]))
    emit("fig16_fidelity", dt * 1e6,
         f"loss {curves['2hop'][0]:.2f}->{curves['2hop'][-1]:.2f} over 30 "
         f"steps; max |2hop - alternative| = {gap:.3f} (same convergence, "
         f"paper Fig 16)")
    assert curves["2hop"][-1] < curves["2hop"][0] - 0.5
    assert gap < 0.05


# ---------------------------------------------------------------------------
# CommEngine — serial vs double-buffered prefetch gather schedules
# ---------------------------------------------------------------------------

def bench_comm_schedules():
    """Per-step wall time + gathered bytes for the serial vs prefetch layer
    schedules on the 8-virtual-device host mesh (p=4, tp=2); seeds the perf
    trajectory in artifacts/benchmarks/BENCH_comm.json.  Runs as a
    subprocess so this process keeps its single CPU device."""
    import pathlib as _pl
    import subprocess
    import sys

    script = _pl.Path(__file__).parent / "comm_bench.py"
    proc = subprocess.run(
        [sys.executable, str(script)],
        capture_output=True, text=True, timeout=1800,
        cwd=str(script.parent.parent),
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": str(_pl.Path.home()), "JAX_PLATFORMS": "cpu"},
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    data = json.loads(proc.stdout[proc.stdout.index("{"):])
    RESULTS["comm_schedules"] = data
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "BENCH_comm.json").write_text(json.dumps(data, indent=1))
    assert data["loss_bitwise_equal"], "prefetch changed the loss!"
    ser, pre = data["serial"], data["prefetch"]
    emit("comm_prefetch_schedule", pre["us_per_step"],
         f"serial={ser['us_per_step']:.0f}us prefetch={pre['us_per_step']:.0f}"
         f"us ({data['speedup']:.2f}x); gathers issued one layer ahead "
         f"(carried={pre['carried_all_gathers']}, serial="
         f"{ser['carried_all_gathers']}); gathered wire bytes/step "
         f"{pre['gathered_wire_bytes']:.2e} vs {ser['gathered_wire_bytes']:.2e}"
         f" (prefetch trades backward re-gathers for carry residuals); "
         f"losses bitwise equal")


# ---------------------------------------------------------------------------
# Table 1 — model zoo parameter counts
# ---------------------------------------------------------------------------

def bench_table1_model_zoo():
    from repro.configs import ASSIGNED, PAPER_CONFIGS
    from repro.models.build import exact_param_count

    out = {}
    for cfg in list(PAPER_CONFIGS.values()) + list(ASSIGNED):
        out[cfg.name] = round(exact_param_count(cfg) / 1e9, 2)
    RESULTS["table1"] = out
    for name, target in (("bert-10b", 10), ("bert-15b", 15), ("bert-20b", 20),
                         ("bert-50b", 50), ("qwen1.5-110b", 111),
                         ("dbrx-132b", 132)):
        got = out[name]
        assert abs(got - target) / target < 0.18, (name, got)
    emit("table1_model_zoo", 0.0,
         "; ".join(f"{k}={v}B" for k, v in out.items()))


# ---------------------------------------------------------------------------
# Roofline table (from dry-run artifacts) + kernel wall-times
# ---------------------------------------------------------------------------

def bench_roofline_table():
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))
    from repro.roofline.analysis import build_table

    rows = build_table()
    RESULTS["roofline"] = rows
    if rows:
        (ART / "roofline.json").write_text(json.dumps(rows, indent=1))
        worst = min(rows, key=lambda r: r["roofline_fraction"])
        best = max(rows, key=lambda r: r["roofline_fraction"])
        emit("roofline_table", 0.0,
             f"{len(rows)} cells; best fraction "
             f"{best['roofline_fraction']:.3f} ({best['arch']}/{best['shape']}), "
             f"worst {worst['roofline_fraction']:.4f} "
             f"({worst['arch']}/{worst['shape']})")
    else:
        emit("roofline_table", 0.0, "no dry-run artifacts found — run "
             "python -m repro.launch.dryrun --all first")


def bench_kernel_walltime():
    import jax
    import jax.numpy as jnp

    from repro.kernels.flash_attention.ref import attention_ref

    q = jnp.ones((4, 256, 64), jnp.float32)
    f = jax.jit(lambda q: attention_ref(q, q, q, causal=True))
    f(q).block_until_ready()
    t0 = time.time()
    for _ in range(5):
        f(q).block_until_ready()
    emit("kernel_attention_ref_cpu", (time.time() - t0) / 5 * 1e6,
         "pure-jnp oracle wall time (Pallas kernel validated interpret=True; "
         "TPU timing n/a on this host)")


BENCHES = [
    bench_fig2_effective_bandwidth,
    bench_fig7_8_scaling,
    bench_fig9_tflops,
    bench_fig10_400g,
    bench_case_study_100b,
    bench_fig11_megatron,
    bench_fig12_partition_group,
    bench_fig13_hierarchical,
    bench_fig14_two_hop,
    bench_fig15_impl_opts,
    bench_fig16_fidelity,
    bench_comm_schedules,
    bench_table1_model_zoo,
    bench_roofline_table,
    bench_kernel_walltime,
]

# figure-cell name -> (bench fn, RESULTS key) for the perf matrix's
# ``figures`` suite.  These are the model-derived paper numbers: cheap,
# deterministic, and gated on EXACT value-hash reproducibility against
# benchmarks/baselines.json — never on timing.  fig15/fig16 actually train
# on CPU, so their floats are jax-version dependent: contract-gated only
# (their internal asserts), full runs only.
FIGURE_BENCHES = {
    "fig2": (bench_fig2_effective_bandwidth, "fig2"),
    "fig7_8": (bench_fig7_8_scaling, "fig7_8"),
    "fig9": (bench_fig9_tflops, "fig9"),
    "fig10": (bench_fig10_400g, "fig10"),
    "case_study_100b": (bench_case_study_100b, "case_study_100b"),
    "fig11": (bench_fig11_megatron, "fig11"),
    "fig12": (bench_fig12_partition_group, "fig12_model"),
    "fig13": (bench_fig13_hierarchical, "fig13_time_ratio"),
    "fig14": (bench_fig14_two_hop, "fig14"),
    "table1": (bench_table1_model_zoo, "table1"),
}
FIGURE_BENCHES_FULL = {
    "fig15": (bench_fig15_impl_opts, "fig15"),
    "fig16": (bench_fig16_fidelity, "fig16"),
}


def matrix_cells_main(full: bool) -> None:
    """``--matrix-cells``: run just the figure benches and print their
    matrix cell records as pure JSON (the ``figures`` suite of
    ``benchmarks/matrix.py``).  The CSV ``emit`` chatter is redirected to
    stderr so stdout stays machine-parseable.  Coverage is pinned to
    ``repro.bench.matrixdef.FIGURE_CELLS`` — a bench this mapping loses
    becomes a loud cell-missing matrix failure."""
    import contextlib
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

    from repro.bench import matrixdef as MD
    from repro.bench import measure as MS

    OUT.mkdir(parents=True, exist_ok=True)
    items = {name: FIGURE_BENCHES[name] for name in MD.FIGURE_CELLS}
    if full:
        items.update({name: FIGURE_BENCHES_FULL[name]
                      for name in MD.FIGURE_CELLS_FULL})
    cells = {}
    with contextlib.redirect_stdout(sys.stderr):
        for name, (bench, key) in items.items():
            config = dict(suite="figures", cell=name, result_key=key)
            err = None
            try:
                bench()
            except Exception as e:  # noqa: BLE001
                err = f"{type(e).__name__}: {e}"
            value = RESULTS.get(key)
            ok = err is None and value is not None
            detail = err or (None if ok
                             else f"result key {key!r} missing")
            if name in FIGURE_BENCHES_FULL:
                cells[f"figures/{name}"] = MS.contract_cell(
                    config, ok, detail=detail)
            else:
                cells[f"figures/{name}"] = MS.exact_cell(
                    config, MS.result_hash(value) if ok else "",
                    ok=ok, detail=detail)
    print(json.dumps({"cells": cells}, indent=1, default=str))


def main() -> None:
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))
    OUT.mkdir(parents=True, exist_ok=True)
    print("name,us_per_call,derived")
    failures = 0
    for bench in BENCHES:
        try:
            bench()
        except Exception as e:  # noqa: BLE001
            failures += 1
            emit(bench.__name__, -1.0, f"FAILED: {type(e).__name__}: {e}")
    (OUT / "results.json").write_text(json.dumps(RESULTS, indent=1, default=str))
    if failures:
        raise SystemExit(f"{failures} benchmarks failed")


if __name__ == "__main__":
    import sys

    if "--matrix-cells" in sys.argv:
        matrix_cells_main(full="--full" in sys.argv)
    else:
        main()
