"""Analytic cluster model reproducing the paper's measured environment.

The container has no GPUs/TPUs, so the paper's throughput figures are
reproduced through the same α-β collective model the paper itself uses for
its analysis (§2.3 footnote 1, §3.2-3.4 cost formulas), calibrated to the
two effective-bandwidth anchors the paper reports from measurement:

    B_part ≈ 128 GB/s   (8 V100s inside one p3dn node, NVLink)
    B_all  ≈ 11 GB/s    (64 GPUs across 8 nodes, 100 Gbps EFA)

Everything else follows from ring-collective algebra:
    T_ag(g, M) = (g-1) * (α + M / (g * B_link(g)))
    B_eff(g, M) = ((g-1)/g * M) / T_ag          (the Fig-2 quantity)
"""

from __future__ import annotations

import dataclasses

from repro.core.linkmodel import EFA_100G, EFA_400G, LinkProfile, get_profile

GB = 1e9

# paper-reported anchors (AWS p3dn.24xlarge) — stored in the shared link
# table (core/linkmodel.py, profile "efa-100g") so the autotuner, the
# roofline and this model read one source of truth.
B_INTRA = EFA_100G.intra.bandwidth * EFA_100G.node_size  # 128 GB/s NVLink/node
B_INTER_NODE = EFA_100G.inter.bandwidth                  # 100 Gbps EFA
ALPHA_INTRA = EFA_100G.intra.alpha
ALPHA_INTER = EFA_100G.inter.alpha
GPUS_PER_NODE = EFA_100G.node_size
V100_PEAK = EFA_100G.peak_flops  # fp16 tensor-core peak
V100_EFF = 0.55             # achievable matmul efficiency w/ checkpointing


@dataclasses.dataclass(frozen=True)
class Net:
    b_intra: float = B_INTRA
    b_inter: float = B_INTER_NODE
    a_intra: float = ALPHA_INTRA
    a_inter: float = ALPHA_INTER
    k: int = GPUS_PER_NODE

    @staticmethod
    def from_profile(profile: str | LinkProfile) -> "Net":
        """Build the calibrated paper net from a shared link profile
        (``b_intra`` is the node-aggregate NVLink figure — per-GPU rail
        bandwidth times node size)."""
        p = get_profile(profile)
        return Net(b_intra=p.intra.bandwidth * p.node_size,
                   b_inter=p.inter.bandwidth,
                   a_intra=p.intra.alpha, a_inter=p.inter.alpha,
                   k=p.node_size)

    def link_bw(self, g: int) -> float:
        """Per-participant ring bandwidth for a g-GPU group.

        Calibrated to the paper's measured anchors: B_part ~= 128 GB/s for 8
        GPUs on NVLink and B_all ~= 11 GB/s at 64 GPUs (their Fig-2 effective
        bandwidth counts the NIC once per ring stage, not divided across the
        node's GPUs — NCCL runs k parallel rings, one per GPU/rail)."""
        if g <= self.k:
            return self.b_intra / self.k * min(g, self.k)
        return self.b_inter

    def alpha(self, g: int) -> float:
        return self.a_intra if g <= self.k else self.a_inter


NET_100G = Net.from_profile(EFA_100G)
NET_400G = Net.from_profile(EFA_400G)    # p4d 400 Gbps
NET_DGX = Net(b_inter=200 * GB)          # DGX-A100 1.6 Tb/s IB


def t_all_gather(net: Net, g: int, m_bytes: float,
                 granularity: float | None = None) -> float:
    """Ring all-gather of a buffer whose *gathered* size is m_bytes.

    granularity: per-collective message size.  DeepSpeed issues one gather
    per parameter tensor, MiCS one per layer (coalesced APIs, paper §4) —
    small messages pay the (g-1)·α latency term once per message, which is
    the whole Fig-2 story."""
    if g <= 1:
        return 0.0
    per_link = net.link_bw(g)
    if granularity is None or granularity >= m_bytes:
        return (g - 1) * (net.alpha(g) + m_bytes / (g * per_link))
    n_msgs = m_bytes / granularity
    per_msg = (g - 1) * (net.alpha(g) + granularity / (g * per_link))
    return n_msgs * per_msg


def t_hier_all_gather(net: Net, g: int, m_bytes: float,
                      granularity: float | None = None) -> float:
    """Paper §3.3 hierarchical all-gather: the slow inter-node phase moves
    (g-k)/g of the buffer instead of (g-1)/g (k parallel channels), then a
    chunk reorder (device-local copy) and the intra-node phase on NVLink."""
    if g <= net.k:
        return t_all_gather(net, g, m_bytes, granularity)
    t_inter = t_all_gather(net, g, m_bytes, granularity) \
        * ((g - net.k) / max(g - 1, 1))
    intra_net = dataclasses.replace(net, a_inter=net.a_intra,
                                    b_inter=net.b_intra)
    t_intra = t_all_gather(intra_net, net.k, m_bytes, granularity)
    t_reorder = m_bytes / (900 * GB)   # device-local copy
    return t_inter + t_intra + t_reorder


def t_reduce_scatter(net: Net, g: int, m_bytes: float) -> float:
    return t_all_gather(net, g, m_bytes)


def t_all_reduce(net: Net, g: int, m_bytes: float) -> float:
    return 2.0 * t_all_gather(net, g, m_bytes)


def effective_bandwidth(net: Net, g: int, m_bytes: float) -> float:
    """Fig 2: effective AG bandwidth seen by each participant."""
    t = t_all_gather(net, g, m_bytes)
    return ((g - 1) / g) * m_bytes / t if t else float("inf")


# ---------------------------------------------------------------------------
# paper workload step-time model (BERT variants, Table 1)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    params: float            # bytes of fp16 parameters = 2 * N
    flops_per_sample: float  # fwd+bwd+remat
    layers: int = 64
    micro_batch: int = 8
    micro_steps: int = 4


def bert_workload(name: str, n_params: float, layers: int,
                  seq: int = 512) -> Workload:
    # 6 N D for fwd+bwd, ~1.33x for activation recomputation
    return Workload(name, params=2.0 * n_params, layers=layers,
                    flops_per_sample=8.0 * n_params * seq)


DS_TENSORS_PER_LAYER = 4     # DeepSpeed gathers per parameter tensor
# Overlap is layer-local (prefetch hides at most the next layer's gather
# behind the current layer's compute), so only a fraction of compute is
# usable cover; coarse stream sync (DeepSpeed, paper §4) blocks most of it.
OVERLAP_FINE = 0.5
OVERLAP_COARSE = 0.15


def step_time(
    w: Workload, net: Net, n: int, p: int, *,
    system: str = "mics", hierarchical: bool = True,
    coalesced: bool = True, fine_sync: bool = True,
    peak: float = V100_PEAK, eff: float = V100_EFF,
) -> float:
    """Modeled time of one optimizer step (s micro-steps).

    system: 'mics' (partition group p, 2-hop), 'zero3' (p=n, per-micro
    global sync) or 'mics_alt' (Fig-14 alternative schedule).
    coalesced/fine_sync=False model the DeepSpeed implementation (per-tensor
    gathers, coarse stream synchronization) for the Fig-15 ablation.
    """
    s = w.micro_steps
    m = w.params
    samples = w.micro_batch
    t_comp = s * samples * w.flops_per_sample / (peak * eff)

    p_eff = n if system == "zero3" else p
    gran = m / w.layers if coalesced else m / (w.layers * DS_TENSORS_PER_LAYER)

    # parameter gathering: fwd + bwd re-gather (2x) per micro-step
    t_flat = t_all_gather(net, p_eff, m, granularity=gran)
    if hierarchical and p_eff > net.k and system != "zero3":
        t_gather = t_hier_all_gather(net, p_eff, m, granularity=gran)
    else:
        t_gather = t_flat
    t_params = 2 * s * t_gather

    # gradient synchronization
    if system == "zero3":
        t_sync = s * t_reduce_scatter(net, n, m)
    elif system == "mics_alt":        # Fig 14 alternative schedule
        t_sync = s * t_all_reduce(net, n, m)
    else:                             # 2-hop
        t_sync = s * t_reduce_scatter(net, p_eff, m)
        if n > p_eff:
            t_sync += t_all_reduce(net, n // p_eff, m / p_eff)

    # prefetch overlaps parameter gathering with compute; the overlap degree
    # is the fine-grained-synchronization story of paper §4
    overlap = OVERLAP_FINE if fine_sync else OVERLAP_COARSE
    exposed = max(0.0, t_params + t_sync - overlap * t_comp)
    return t_comp + exposed


def throughput(w: Workload, net: Net, n: int, p: int, **kw) -> float:
    """samples / second for the whole cluster."""
    t = step_time(w, net, n, p, **kw)
    return n * w.micro_batch * w.micro_steps / t
