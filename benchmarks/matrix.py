"""Declarative perf-matrix runner — the repo's single CI bench gate.

  PYTHONPATH=src python benchmarks/matrix.py [--smoke] [--check]
      [--suites comm,serve,...] [--out BENCH_matrix.json] [--list]

Runs every bench suite (comm, serve, memplan, elastic, chaos, figures) as
declared in ``repro.bench.matrixdef``, measures each cell through the
shared core (warmup discard, N repeats, median + MAD/IQR), applies the
variance-aware regression gates (a cell fails only when it exceeds both
the threshold and the measured noise band — vs its in-run reference cell
and, when curated, the checked-in ``benchmarks/baselines.json``), and
emits ONE trajectory-friendly ``BENCH_matrix.json`` with per-cell
provenance: config hash, timing samples, variance, gate verdicts,
predicted-vs-measured ratios.

``--check`` exits nonzero on any enforced gate failure; the report is
still written first, so CI's ``if: always()`` artifact upload keeps the
ledger.  See docs/benchmarks.md for the config schema and the baseline
refresh recipe (tools/update_baseline.py).
"""

import sys

from repro.bench.runner import main

if __name__ == "__main__":
    sys.exit(main())
