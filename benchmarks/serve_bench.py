"""Closed-loop continuous-batching serving bench on the 8-device host mesh.

Two engines over the same seeded request trace:

* **paged** — the continuous-batching engine (runtime/batching.py scheduler
  over runtime/paged.py block-pool KV): chunked prefill interleaved with
  decode, FIFO admission under the free-block budget, per-request seeded
  sampling;
* **fixed** — the static baseline (runtime/serving.build_serve_steps):
  requests grouped in arrival order into full batches, prompts padded to
  the global max, the whole group decoded to its longest request
  (head-of-line blocking + padding waste — what continuous batching
  exists to beat).

Both engines get the SAME per-rank KV memory budget: the fixed cache
reserves ``FIXED_ROWS_LOCAL * CAP`` token slots per rank, the paged pool
``(NB_LOCAL - 1) * BLOCK_SIZE`` — equal by construction.  Because real
sequences never fill CAP, block-granular allocation turns that budget
into more resident requests (6 slots/rank vs 4 rows/rank under full
reservation), which is the whole vLLM-style argument: fragmentation
becomes throughput.  On top of that, continuous batching retires each
request the tick it finishes, while the static baseline decodes every
group to its longest member (head-of-line padding waste).

The arrival-rate sweep offers ``rate`` requests per scheduler tick; the
tick -> wall-clock mapping comes from the measured engine steps, so each
cell reports real p50/p99 TTFT + end-to-end latency seconds and generated
tokens/s, plus the link-model predicted decode-step time
(``core/autotune.cost_decode_step``) against the measured mean.

Two correctness/overhead sections ride along:

* ``equivalence`` replays the paged-vs-contiguous bitwise check (fp32 KV,
  block-straddling prompts, GQA head-slot replication) — the engine
  property every throughput number rests on;
* ``step_overhead`` times every step kind both engines issue with
  alternating interleaved reps (same-process back-to-back, so JIT and
  machine-drift bias cancels).  The regression gate is the per-ROW decode
  ratio — the paged step pushes 1.5x the rows per call, so raw step
  times are not directly comparable.  The same controlled prices feed the
  ``normalized`` tokens/s in every sweep cell: wall clocks on this
  oversubscribed CPU harness drift 2-3x between cells, but the scheduler
  tick/step counts are deterministic, so pricing them with interleaved
  timings is the noise-immune throughput comparison.

An ``overload`` cell rides along: a tick-0 burst through the resilient
serve loop (runtime/resilient.py) with a bounded queue, tight deadlines
and the memplan-priced degradation ladder — the overload-control contract
(typed shedding, ladder engage/restore, 100%-accounted lifecycle ledger,
no deadlock) gated on the real engine.  Every sweep cell also carries the
batcher's request-lifecycle ledger (queue-depth and wait-age percentiles,
shed/evict/replay counters).

  PYTHONPATH=src python benchmarks/serve_bench.py [--smoke] [--check]

This script is the ``serve`` suite of the declarative perf matrix
(``benchmarks/matrix.py``); its ``cells`` section carries the standard
per-cell records (repro.bench.measure) — the four interleaved step kinds
as timing cells (the paged/fixed decode comparison is per-ROW,
``normalize_by="rows"``, because the paged step pushes 1.5x the rows),
the bitwise equivalence and every sweep/overload cell as contract cells.
``--check`` is a thin shim applying exactly the gates
``repro.bench.matrixdef`` declares for this suite: it fails on any
paged-vs-contiguous mismatch, when the per-row decode overhead regresses
significantly (variance-aware, vs the same-run fixed reference), or when
the overload cell breaks the shed/ladder/ledger contract; the full run
must additionally show paged normalized tokens/s beating the baseline in
the saturation cell (``rate=inf`` — every request offered at tick 0).
Output JSON is saved as BENCH_serve.json (BENCH_serve_smoke.json in CI).
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.bench import measure as MS
from repro.bench.matrixdef import (
    SERVE_RATES_FULL, SERVE_RATES_SMOKE, SERVE_STEP_KINDS,
)
from repro.configs import get_config, smoke_variant
from repro.core.autotune import cost_decode_step
from repro.core.comm import policies_from_config
from repro.core.linkmodel import get_profile
from repro.core.mics import MiCSConfig, init_state
from repro.core.topology import MiCSTopology, make_host_mesh
from repro.models.build import build_model
from repro.runtime import paged as PG
from repro.core.memplan import degradation_levels
from repro.runtime.batching import ContinuousBatcher, DegradationLadder, Request
from repro.runtime.resilient import ResilientServeLoop, ServeLoopConfig
from repro.runtime.serving import build_serve_steps, global_cache_shapes

BLOCK_SIZE = 8
MAX_BLOCKS = 4
CAP = BLOCK_SIZE * MAX_BLOCKS          # positions per request (both engines)
FIXED_ROWS_LOCAL = 4                   # baseline batch rows per data rank
SLOTS_LOCAL = 6                        # paged slots per rank (1.5x the rows:
#   what the shared block budget sustains for the chat-shaped trace)
CHUNK = 8                              # prefill tokens per tick (>= max plen)
# equal KV budget: usable pool slots/rank == the fixed cache's token slots
NB_LOCAL = FIXED_ROWS_LOCAL * CAP // BLOCK_SIZE + 1  # +1: garbage block 0
# offered requests per tick; inf = the saturation cell (all at tick 0).
# Labels pinned by repro.bench.matrixdef.SERVE_RATES_* — the declared
# matrix cells — so coverage drift fails the matrix loudly.
RATES = tuple(float(r) for r in SERVE_RATES_FULL)
SMOKE_RATES = tuple(float(r) for r in SERVE_RATES_SMOKE)
N_REQUESTS = 32
SMOKE_REQUESTS = 10
PROFILE = "v5e"


def make_trace(n: int, vocab: int, rng: np.random.Generator) -> list[Request]:
    """Seeded decode-dominated workload (chat-shaped: short prompts, long
    variable generations); positions always fit CAP."""
    reqs = []
    for i in range(n):
        plen = int(rng.integers(4, 9))
        max_new = int(rng.integers(4, 25))
        reqs.append(Request(
            rid=i,
            prompt=rng.integers(1, vocab, plen).astype(int).tolist(),
            max_new_tokens=max_new,
            temperature=0.7,
            seed=1000 + i,
        ))
    return reqs


def build_engines(model, topo, mcfg):
    """Two paged steps share one pool: a chunked one for ticks with prefill
    rows in flight and a chunk=1 decode-only fast path for steady state
    (most ticks — paying chunk x compute on pure-decode ticks is what made
    naive chunked prefill lose to the static baseline)."""
    step_chunk = PG.build_paged_step(
        model, topo, mcfg, max_blocks=MAX_BLOCKS, block_size=BLOCK_SIZE,
        chunk=CHUNK, top_k=8)
    step_one = PG.build_paged_step(
        model, topo, mcfg, max_blocks=MAX_BLOCKS, block_size=BLOCK_SIZE,
        chunk=1, top_k=8)
    prefill_fn, decode_fn = build_serve_steps(
        model, topo, mcfg, cache_len=CAP, top_k=8)
    return step_chunk, step_one, prefill_fn, decode_fn


def run_continuous(model, topo, mcfg, step_chunk, step_one, reqs,
                   arrival_ticks):
    """One closed-loop paged cell.  Returns the stats + wall timeline."""
    dp = topo.data_parallel_size
    batcher = ContinuousBatcher(
        dp=dp, slots_local=SLOTS_LOCAL, nb_local=NB_LOCAL,
        block_size=BLOCK_SIZE, max_blocks=MAX_BLOCKS, chunk=CHUNK,
        reserve="full")
    caches, _ = PG.init_paged_caches(
        model, topo, NB_LOCAL, BLOCK_SIZE, mcfg.kv_dtype)
    state = init_state(model, topo, seed=7)
    params = state["params"]

    # warm both compile caches outside the timed loop (donation: rebuild)
    B = batcher.batch
    zero = lambda shape, dt: jnp.zeros(shape, dt)
    for step, c in ((step_chunk, CHUNK), (step_one, 1)):
        out = step(params, caches, zero((B, c), jnp.int32),
                   zero((B,), jnp.int32), zero((B,), jnp.int32),
                   zero((B, MAX_BLOCKS), jnp.int32),
                   zero((B,), jnp.int32), zero((B,), jnp.float32))
        jax.block_until_ready(out[0])
        caches = out[2]
    caches, _ = PG.init_paged_caches(
        model, topo, NB_LOCAL, BLOCK_SIZE, mcfg.kv_dtype)

    pending = sorted(zip(arrival_ticks, reqs), key=lambda p: (p[0], p[1].rid))
    wall = [0.0]
    step_times = []
    decode_step_times = []
    resident_rows = []
    while pending or not batcher.idle:
        while pending and pending[0][0] <= batcher.tick:
            _, req = pending.pop(0)
            req.arrival = batcher.tick
            batcher.submit(req)
        plan = batcher.plan_step()
        if plan.active_rows == 0:
            # nothing resident yet: an idle tick costs no wall time
            batcher.commit(plan, np.zeros(batcher.batch, np.int64))
            wall.append(wall[-1])
            continue
        decode_only = int(plan.n_new.max()) <= 1
        step = step_one if decode_only else step_chunk
        tokens = plan.tokens[:, :1] if decode_only else plan.tokens
        t0 = time.perf_counter()
        tok, _logits, caches = step(
            params, caches,
            jnp.asarray(tokens), jnp.asarray(plan.pos),
            jnp.asarray(plan.n_new), jnp.asarray(plan.tables),
            jnp.asarray(plan.seeds), jnp.asarray(plan.temps))
        tok = np.asarray(tok)
        dt = time.perf_counter() - t0
        step_times.append(dt)
        if decode_only:
            decode_step_times.append(dt)
        resident_rows.append(plan.active_rows)
        wall.append(wall[-1] + dt)
        batcher.commit(plan, tok)

    ttft, lat = [], []
    for r in batcher.finished:
        ttft.append(wall[min(r.first_token_tick + 1, len(wall) - 1)]
                    - wall[r.arrival])
        lat.append(wall[min(r.finish_tick + 1, len(wall) - 1)]
                   - wall[r.arrival])
    tokens = sum(len(r.generated) for r in batcher.finished)
    stats = batcher.stats()
    stats.update(
        wall_s=wall[-1],
        tokens_per_s=tokens / wall[-1] if wall[-1] else 0.0,
        ttft_s_p50=float(np.percentile(ttft, 50)) if ttft else 0.0,
        ttft_s_p99=float(np.percentile(ttft, 99)) if ttft else 0.0,
        latency_s_p50=float(np.percentile(lat, 50)) if lat else 0.0,
        latency_s_p99=float(np.percentile(lat, 99)) if lat else 0.0,
        measured_step_s_mean=float(np.mean(step_times)) if step_times else 0.0,
        measured_decode_step_s_mean=float(np.mean(decode_step_times))
        if decode_step_times else 0.0,
        ticks_active=len(step_times),
        decode_only_ticks=len(decode_step_times),
        mean_resident_rows=float(np.mean(resident_rows))
        if resident_rows else 0.0,
        ledger=batcher.ledger(),
    )
    return stats


def run_fixed(model, topo, mcfg, prefill_fn, decode_fn, reqs, arrival_s,
              params, max_plen):
    """Static baseline: arrival-order groups of B, padded, head-of-line."""
    B = topo.data_parallel_size * FIXED_ROWS_LOCAL
    groups = [reqs[i:i + B] for i in range(0, len(reqs), B)]
    t_end = 0.0
    step_times = []
    lat, ttft = [], []
    tokens = 0
    decode_steps = 0
    for gi, group in enumerate(groups):
        idx = list(range(gi * B, gi * B + len(group)))
        start = max([t_end] + [arrival_s[i] for i in idx])
        toks = np.zeros((B, max_plen), np.int32)
        temps = np.zeros(B, np.float32)
        seeds = np.zeros(B, np.int32)
        for j, r in enumerate(group):
            toks[j, :len(r.prompt)] = r.prompt
            temps[j] = r.temperature
            seeds[j] = r.seed
        t0 = time.perf_counter()
        logits, caches = prefill_fn(params, {"tokens": jnp.asarray(toks)})
        vocab = model.cfg.vocab
        tok = jnp.argmax(jnp.asarray(logits[:, -1:, :vocab], jnp.float32),
                         axis=-1).astype(jnp.int32)
        jax.block_until_ready(tok)
        t_pre = time.perf_counter() - t0
        elapsed = t_pre
        n_steps = max(r.max_new_tokens for r in group)
        decode_steps += n_steps - 1
        row_mask = jnp.arange(B) < len(group)
        for i in range(n_steps - 1):
            t0 = time.perf_counter()
            _lg, tok, caches = decode_fn(
                params, caches, tok, jnp.int32(max_plen + i),
                jnp.asarray(seeds), jnp.asarray(temps), row_mask)
            tok = jnp.asarray(np.asarray(tok))  # block; feed back
            dt = time.perf_counter() - t0
            if gi > 0 or i > 0:   # first decode step pays the compile
                step_times.append(dt)
            elapsed += dt
        t_end = start + elapsed
        for j, r in enumerate(group):
            ttft.append(start + t_pre - arrival_s[idx[j]])
            lat.append(t_end - arrival_s[idx[j]])
            tokens += r.max_new_tokens
    return {
        "wall_s": t_end,
        "tokens_per_s": tokens / t_end if t_end else 0.0,
        "ttft_s_p50": float(np.percentile(ttft, 50)),
        "ttft_s_p99": float(np.percentile(ttft, 99)),
        "latency_s_p50": float(np.percentile(lat, 50)),
        "latency_s_p99": float(np.percentile(lat, 99)),
        "measured_step_s_mean": float(np.mean(step_times))
        if step_times else 0.0,
        "groups": len(groups),
        "decode_steps": decode_steps,
        "tokens": tokens,
    }


def step_overhead(model, topo, mcfg, step_chunk, step_one, prefill_fn,
                  decode_fn, params, max_plen: int, reps: int = 20,
                  warmup: int = 2):
    """Interleaved timing of every step kind both engines issue.

    All four step kinds run back-to-back inside each rep, so JIT/allocator
    warmup and machine drift hit them equally — these are the controlled
    per-step prices the normalized throughput gate uses.  The regression
    gate is the per-ROW decode ratio: the paged step pushes
    ``SLOTS_LOCAL/FIXED_ROWS_LOCAL`` times the rows per call, so raw step
    times are not directly comparable (``normalize_by="rows"`` in the
    matrix gate).  Returns ``(summary, timings)`` where ``timings`` maps
    each step kind to its :class:`repro.bench.measure.TimingStats` (the
    matrix's ``serve/step/*`` cells).
    """
    dp = topo.data_parallel_size
    Bp, Bf = dp * SLOTS_LOCAL, dp * FIXED_ROWS_LOCAL
    pool, _ = PG.init_paged_caches(
        model, topo, NB_LOCAL, BLOCK_SIZE, mcfg.kv_dtype)
    tmpl, _ = global_cache_shapes(model, topo, Bf, CAP)
    cc = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), tmpl)
    tok1 = jnp.ones((Bp, 1), jnp.int32)
    tokc = jnp.ones((Bp, CHUNK), jnp.int32)
    tokf = jnp.ones((Bf, 1), jnp.int32)
    pref_batch = {"tokens": jnp.ones((Bf, max_plen), jnp.int32)}
    zp_i = jnp.zeros(Bp, jnp.int32)
    one_p = jnp.ones(Bp, jnp.int32)
    full_p = jnp.full((Bp,), CHUNK, jnp.int32)
    tabs = jnp.ones((Bp, MAX_BLOCKS), jnp.int32)
    zp_f = jnp.zeros(Bp, jnp.float32)
    zf_i = jnp.zeros(Bf, jnp.int32)
    zf_f = jnp.zeros(Bf, jnp.float32)
    mask = jnp.ones(Bf, bool)
    acc = {kind: [] for kind in SERVE_STEP_KINDS}
    for i in range(reps + warmup):
        t0 = time.perf_counter()
        t, _lg, pool = step_one(params, pool, tok1, zp_i, one_p, tabs,
                                zp_i, zp_f)
        jax.block_until_ready(t)
        d_pd = time.perf_counter() - t0
        t0 = time.perf_counter()
        t, _lg, pool = step_chunk(params, pool, tokc, zp_i, full_p, tabs,
                                  zp_i, zp_f)
        jax.block_until_ready(t)
        d_pc = time.perf_counter() - t0
        t0 = time.perf_counter()
        _lg, t2, cc = decode_fn(params, cc, tokf, jnp.int32(3),
                                zf_i, zf_f, mask)
        jax.block_until_ready(t2)
        d_fd = time.perf_counter() - t0
        t0 = time.perf_counter()
        lg, _caches = prefill_fn(params, pref_batch)
        jax.block_until_ready(lg)
        d_fp = time.perf_counter() - t0
        if i >= warmup:  # first interleaved rounds pay the compiles
            acc["paged_decode"].append(d_pd)
            acc["paged_chunk"].append(d_pc)
            acc["fixed_decode"].append(d_fd)
            acc["fixed_prefill"].append(d_fp)
    timings = {k: MS.TimingStats(tuple(v), warmup=warmup)
               for k, v in acc.items()}
    out = {k + "_s": float(np.mean(v)) for k, v in acc.items()}
    out.update(paged_rows=Bp, fixed_rows=Bf, reps=reps, warmup=warmup,
               timing={k: t.to_dict() for k, t in timings.items()})
    out["per_row_ratio"] = ((out["paged_decode_s"] / Bp)
                            / (out["fixed_decode_s"] / Bf)
                            if out["fixed_decode_s"] else float("inf"))
    return out, timings


def normalized_throughput(cont: dict, fixed: dict, so: dict) -> dict:
    """Price each engine's deterministic schedule with the controlled
    interleaved step timings — raw wall clocks on the oversubscribed
    8-virtual-device CPU harness drift 2-3x between cells, but the
    scheduler's tick/step counts are exact, so this is the noise-immune
    tokens/s comparison the gate uses."""
    chunk_ticks = cont["ticks_active"] - cont["decode_only_ticks"]
    pt = (cont["decode_only_ticks"] * so["paged_decode_s"]
          + chunk_ticks * so["paged_chunk_s"])
    ft = (fixed["decode_steps"] * so["fixed_decode_s"]
          + fixed["groups"] * so["fixed_prefill_s"])
    paged_tps = cont["tokens_generated"] / pt if pt else 0.0
    fixed_tps = fixed["tokens"] / ft if ft else 0.0
    return {"paged_compute_s": pt, "fixed_compute_s": ft,
            "paged_tokens_per_s": paged_tps, "fixed_tokens_per_s": fixed_tps,
            "ratio": paged_tps / fixed_tps if fixed_tps else float("inf")}


def bitwise_equivalence(model, topo, params) -> dict:
    """Paged decode vs the contiguous vector-position reference, bitwise.

    fp32 KV, block size 4 (prompt 7 straddles a block boundary), greedy;
    the mesh's tp=4 > n_kv_heads exercises GQA head-slot replication.
    """
    BS, MB = 4, 4
    cap = BS * MB
    prompt_lens = [3, 7, 5, 9]
    B, steps = 4, 4
    mcfg = MiCSConfig(gather_dtype=jnp.float32, kv_dtype="fp32",
                      kv_block_size=BS)
    rng = np.random.default_rng(0)
    prompts = rng.integers(1, model.cfg.vocab, (B, max(prompt_lens)))

    prefill_fn, _ = build_serve_steps(model, topo, mcfg, cap)
    tmpl, _specs = global_cache_shapes(model, topo, B, cap)
    caches_ref = jax.tree.map(lambda s: jnp.zeros(s.shape, jnp.float32), tmpl)
    last_logits = np.zeros((B, model.vocab_padded), np.float32)
    for b in range(B):
        n = prompt_lens[b]
        row = {"tokens": jnp.asarray(
            np.broadcast_to(prompts[b:b + 1, :n], (B, n)).astype(np.int32))}
        logits, caches_b = prefill_fn(params, row)

        def put(dst, src):
            return dst.at[:, b].set(
                jnp.asarray(np.asarray(src)[:, b]).astype(dst.dtype))
        caches_ref = jax.tree.map(put, caches_ref, caches_b)
        last_logits[b] = np.asarray(logits)[b, -1]

    step_ref = PG.build_contiguous_step(model, topo, mcfg, cap)
    step_paged = PG.build_paged_step(model, topo, mcfg, max_blocks=MB,
                                     block_size=BS, chunk=1, kv_dtype="fp32")
    dp = topo.data_parallel_size
    nbl = 16
    allocs = [PG.PagedKVAllocator(nbl, BS) for _ in range(dp)]
    tables = np.zeros((B, MB), np.int32)
    for b in range(B):
        blocks = allocs[b // (B // dp)].alloc(
            PG.blocks_for(prompt_lens[b] + steps, BS))
        tables[b, :len(blocks)] = blocks
    pg_caches, _ = PG.init_paged_caches(model, topo, nbl, BS, "fp32")
    pg_caches = PG.pages_from_contiguous(
        model, topo, caches_ref, pg_caches, tables, prompt_lens,
        block_size=BS, kv_dtype="fp32")

    tok0 = np.argmax(last_logits[:, :model.cfg.vocab], -1).astype(np.int32)
    pos = np.asarray(prompt_lens, np.int32)
    seeds = np.arange(B, dtype=np.int32) * 101
    temps = np.zeros(B, np.float32)
    tok_r = tok_p = jnp.asarray(tok0[:, None])
    ok_tok = ok_log = True
    for s in range(steps):
        p = jnp.asarray(pos + s)
        tr, lr, caches_ref = step_ref(params, caches_ref, tok_r, p,
                                      jnp.asarray(seeds), jnp.asarray(temps))
        tp_, lp, pg_caches = step_paged(
            params, pg_caches, tok_p, p, jnp.ones(B, jnp.int32),
            jnp.asarray(tables), jnp.asarray(seeds), jnp.asarray(temps))
        ok_tok &= bool(np.array_equal(np.asarray(tr), np.asarray(tp_)))
        ok_log &= bool(np.array_equal(
            np.asarray(lr).view(np.uint32), np.asarray(lp).view(np.uint32)))
        tok_r = tr[:, None].astype(jnp.int32)
        tok_p = tp_[:, None].astype(jnp.int32)
    return {"tokens_bitwise": ok_tok, "logits_bitwise": ok_log,
            "block_size": BS, "kv_dtype": "fp32", "steps": steps}


def overload_cell(model, topo, mcfg, n: int) -> dict:
    """Burst overload through the resilient serve loop: ``n`` requests at
    tick 0 against 4 resident rows and a 12-deep bounded queue, with tight
    deadlines on a few and the degradation ladder armed.

    The gate (``check``) asserts the overload-control contract end to end
    on the real engine: typed shedding engages (queue-full + deadline),
    the ladder tightens residency under pressure and restores when it
    clears, the lifecycle ledger accounts 100% of submissions, and the
    loop drains — no deadlock, no silent drops.

    The ladder levels are priced by ``memplan.degradation_levels`` but
    truncated to the residency-tightening rung so the cell stays at the
    configured KV dtype (a kv downshift would recompile the engine and
    change numerics — exercised by tests/serve_chaos_harness.py instead).
    """
    gp, sp = policies_from_config(mcfg)
    levels = degradation_levels(
        model, topo, gp, sp, hbm_bytes=2 * (1 << 30), ctx_len=CAP,
        kv_block_size=BLOCK_SIZE, kv_ceiling=mcfg.kv_dtype)[:2]
    ladder = DegradationLadder(levels, high_water=0.6, low_water=0.2,
                               dwell=2)
    sc = ServeLoopConfig(
        slots_local=2, nb_local=NB_LOCAL, block_size=BLOCK_SIZE,
        max_blocks=MAX_BLOCKS, chunk=CHUNK, top_k=8, reserve="full",
        max_queue=12, evict_cap=2, backoff_base=2, backoff_seed=11, seed=7)
    reqs = make_trace(n, model.cfg.vocab, np.random.default_rng(43))
    for r in reqs[2:5]:
        r.deadline_tick = 2              # unreachable: typed shed at submit
    loop = ResilientServeLoop(model, topo, mcfg, sc, ladder=ladder)
    rep = loop.run(reqs, [0] * len(reqs))
    return {
        "offered": len(reqs),
        "completed_rids": sorted(rep["completions"]),
        "shed": rep["shed"],
        "ledger": rep["ledger"],
        "ladder_levels": levels,
        "ladder_transitions": rep["ladder_transitions"],
        "ladder_max_level": rep["ladder_max_level"],
        "ladder_level": rep["ladder_level"],
        "ticks": rep["ticks"],
    }


def run(smoke: bool) -> dict:
    cfg = smoke_variant(get_config("llama3.2-1b"))
    # GQA path: tp=4 over 2 KV heads -> head-slot replication; dp=2
    topo = MiCSTopology(make_host_mesh(1, 1, 2, 4))
    model = build_model(cfg, tp=topo.model_size)
    state = init_state(model, topo, seed=7)
    params = state["params"]

    mcfg = MiCSConfig(kv_dtype="bf16", kv_block_size=BLOCK_SIZE)
    step_chunk, step_one, prefill_fn, decode_fn = build_engines(
        model, topo, mcfg)

    n = SMOKE_REQUESTS if smoke else N_REQUESTS
    rates = SMOKE_RATES if smoke else RATES
    vocab = model.cfg.vocab
    trace = make_trace(n, vocab, np.random.default_rng(42))
    max_plen = max(len(r.prompt) for r in trace)

    gp, _sp = policies_from_config(mcfg)
    profile = get_profile(PROFILE)
    eq = bitwise_equivalence(model, topo, params)
    so, so_timings = step_overhead(model, topo, mcfg, step_chunk, step_one,
                                   prefill_fn, decode_fn, params, max_plen)
    out = {"mesh": {"data": topo.data_parallel_size,
                    "model": topo.model_size},
           "block_size": BLOCK_SIZE, "max_blocks": MAX_BLOCKS,
           "chunk": CHUNK, "slots": topo.data_parallel_size * SLOTS_LOCAL,
           "fixed_rows": topo.data_parallel_size * FIXED_ROWS_LOCAL,
           "kv_token_slots_per_rank": {
               "paged": (NB_LOCAL - 1) * BLOCK_SIZE,
               "fixed": FIXED_ROWS_LOCAL * CAP},
           "n_requests": n, "kv_dtype": mcfg.kv_dtype,
           "equivalence": eq,
           "step_overhead": so,
           "sweep": {}}
    for rate in rates:
        arrival_ticks = [int(i / rate) for i in range(n)]
        reqs = make_trace(n, vocab, np.random.default_rng(42))  # fresh state
        cont = run_continuous(model, topo, mcfg, step_chunk, step_one, reqs,
                              arrival_ticks)
        # the offered-load timeline in seconds, shared by both engines
        n_ticks = max(cont["ticks"], 1)
        t_tick = cont["wall_s"] / n_ticks
        arrival_s = [t * t_tick for t in arrival_ticks]
        fixed = run_fixed(model, topo, mcfg, prefill_fn, decode_fn,
                          make_trace(n, vocab, np.random.default_rng(42)),
                          arrival_s, params, max_plen)
        pred = cost_decode_step(
            model, topo, profile, gp,
            resident=SLOTS_LOCAL, ctx_len=CAP, kv_dtype=mcfg.kv_dtype,
            chunk=1)
        out["sweep"][str(rate)] = {
            "rate_req_per_tick": rate,
            "paged": cont,
            "fixed": fixed,
            "normalized": normalized_throughput(cont, fixed,
                                                out["step_overhead"]),
            "tokens_per_s_ratio": (
                cont["tokens_per_s"] / fixed["tokens_per_s"]
                if fixed["tokens_per_s"] else float("inf")),
            "predicted_decode_step_s": pred["t_step_s"],
            "predicted_breakdown": pred,
            "measured_decode_step_s": cont["measured_decode_step_s_mean"],
        }
    top = out["sweep"][str(rates[-1])]   # the saturation cell
    out["paged_beats_fixed_at_peak"] = top["normalized"]["ratio"] > 1.0
    out["overload"] = overload_cell(model, topo, mcfg,
                                    n=16 if smoke else 24)
    out["cells"] = matrix_cells(out, cfg, mcfg, so_timings, rates, smoke)
    return out


def matrix_cells(out, cfg, mcfg, so_timings, rates, smoke) -> dict:
    """The serve suite's standard per-cell records (repro.bench.measure):
    the four interleaved step kinds as timing cells, the bitwise
    equivalence + every sweep/overload cell as contract cells, each
    carrying its verdict and the metrics the matrix gates read
    (``rows`` for the per-row decode ratio, ``normalized_ratio`` for the
    saturation throughput bound)."""
    so = out["step_overhead"]
    base = dict(suite="serve", mesh=out["mesh"], model=cfg.name,
                block_size=BLOCK_SIZE, max_blocks=MAX_BLOCKS, chunk=CHUNK,
                kv_dtype=mcfg.kv_dtype, n_requests=out["n_requests"],
                smoke=smoke)
    rows = {"paged_decode": so["paged_rows"], "paged_chunk": so["paged_rows"],
            "fixed_decode": so["fixed_rows"],
            "fixed_prefill": so["fixed_rows"]}
    cells = {}
    for kind in SERVE_STEP_KINDS:
        cells[f"serve/step/{kind}"] = MS.timing_cell(
            dict(base, section="step", cell=kind, reps=so["reps"],
                 warmup=so["warmup"]),
            so_timings[kind], metrics={"rows": rows[kind]})
    eq = out["equivalence"]
    eq_ok = eq["tokens_bitwise"] and eq["logits_bitwise"]
    cells["serve/equivalence"] = MS.contract_cell(
        dict(base, section="equivalence", cell="bitwise",
             eq_block_size=eq["block_size"], eq_kv_dtype=eq["kv_dtype"],
             eq_steps=eq["steps"]),
        eq_ok, detail=None if eq_ok else "paged diverged from contiguous")
    for rate in rates:
        cell = out["sweep"][str(rate)]
        led = cell["paged"]["ledger"]
        ok = (cell["paged"]["finished"] == out["n_requests"]
              and cell["predicted_decode_step_s"] > 0
              and bool(led["accounted"]))
        cells[f"serve/rate/{rate}"] = MS.contract_cell(
            dict(base, section="rate", cell=str(rate)),
            ok,
            metrics={
                "normalized_ratio": cell["normalized"]["ratio"],
                "tokens_per_s_ratio": cell["tokens_per_s_ratio"],
                "predicted_decode_step_s": cell["predicted_decode_step_s"],
                "measured_decode_step_s": cell["measured_decode_step_s"],
            },
            detail=None if ok else
            "unfinished requests or unaccounted ledger")
    ov = out["overload"]
    led = ov["ledger"]
    ov_ok = (bool(led["accounted"]) and led["in_flight"] == 0
             and led["shed"] > 0 and led["completed"] > 0
             and sum(led["shed_by_reason"].values()) == led["shed"]
             and ov["ladder_max_level"] >= 1 and ov["ladder_level"] == 0)
    cells["serve/overload"] = MS.contract_cell(
        dict(base, section="overload", cell="burst", offered=ov["offered"]),
        ov_ok,
        metrics={"shed": led["shed"], "completed": led["completed"],
                 "ladder_max_level": ov["ladder_max_level"]},
        detail=None if ov_ok else "shed/ladder/ledger contract broke")
    return cells


def check(out: dict, smoke: bool) -> None:
    """The standalone gate shim: apply exactly the matrix's declared gates
    for the ``serve`` suite (contracts + the variance-aware per-row decode
    ratio; the saturation throughput bound only in full runs)."""
    from repro.bench.runner import check_suite

    failures = check_suite("serve", out, smoke=smoke)
    if failures:
        print("serve bench gate FAILED:\n  " + "\n  ".join(failures),
              file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: fewer requests and rates")
    ap.add_argument("--check", action="store_true",
                    help="assert the gate invariants after printing JSON")
    args = ap.parse_args()
    result = run(args.smoke)
    print(json.dumps(result, indent=1))
    if args.check:
        check(result, args.smoke)
